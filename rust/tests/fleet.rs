//! Fleet lifecycle integration tests: atomic hot-swap under load, A/B
//! routing convergence through the engine, the shadow-calibration →
//! requantize → promote loop end-to-end, and typed worker-side rejections.
//!
//! Hermetic — everything runs on the built-in synthetic arch with he-init
//! weights, no AOT artifacts.  Swap losslessness is a *property* test:
//! promote / rollback / re-weight at randomized instants while clients
//! hammer the engine, and every request must still get exactly one reply
//! with the exact bits the frozen grid produces.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use qft::backend::{self, BackendKind, Scratch};
use qft::data::{Dataset, Split};
use qft::fleet::{Fleet, FleetOptions, Slot};
use qft::quant::deploy::Mode;
use qft::serve::{run_closed_loop, Engine, Reject, ServeConfig};
use qft::Tensor;

fn load_lw() -> Arc<Fleet> {
    Fleet::load(
        Path::new("artifacts_nonexistent_for_test"),
        &[("synthetic".to_string(), BackendKind::Int(Mode::Lw))],
    )
    .unwrap()
}

/// Install a bit-identical twin of the slot's v1 (same kind, same params,
/// fresh prepare) and return its version id.
fn install_twin(slot: &Slot) -> u32 {
    let v1 = slot.primary();
    let model = backend::prepare(v1.kind, &slot.arch, &v1.params);
    slot.install(v1.kind, model, v1.params.clone(), "twin".into()).unwrap()
}

fn xorshift(s: &mut u64) -> u64 {
    *s ^= *s << 13;
    *s ^= *s >> 7;
    *s ^= *s << 17;
    *s
}

#[test]
fn hot_swap_neither_drops_nor_duplicates_under_randomized_churn() {
    // an admin thread promotes / rolls back / re-weights at random while 8
    // clients push through a tiny queue: every request gets exactly one
    // reply, and every served request lands on exactly one version counter
    let fleet = load_lw();
    let slot = fleet.slot(0).unwrap().clone();
    let v2 = install_twin(&slot);
    let cfg = ServeConfig {
        workers: 4,
        max_batch: 4,
        max_wait: Duration::from_micros(100),
        queue_cap: 8,
        ..Default::default()
    };
    let engine = Engine::start(fleet.clone(), &cfg);
    let clients = 8u64;
    let per_client = 40u64;
    let done = AtomicBool::new(false);
    let seen: Mutex<Vec<u64>> = Mutex::new(Vec::new());

    std::thread::scope(|s| {
        let slot_ref = &slot;
        let done_ref = &done;
        let admin = s.spawn(move || {
            let mut rng = 0x9E37_79B9_7F4A_7C15u64;
            let mut churns = 0u64;
            while !done_ref.load(Ordering::Relaxed) {
                match xorshift(&mut rng) % 4 {
                    0 => slot_ref.promote(v2).unwrap(),
                    1 => slot_ref.promote(1).unwrap(),
                    2 => slot_ref.rollback(),
                    _ => {
                        let w = (xorshift(&mut rng) % 10_001) as u32;
                        slot_ref.set_ab(1, v2, w).unwrap();
                    }
                }
                churns += 1;
                std::thread::sleep(Duration::from_micros(50));
            }
            churns
        });
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let client = engine.client();
                let seen = &seen;
                s.spawn(move || {
                    let ds = Dataset::new(c);
                    for i in 0..per_client {
                        let (img, _) = ds.sample(Split::Val, i);
                        let rep = client
                            .infer_timeout(0, img, Duration::from_secs(60))
                            .expect("request dropped during churn");
                        assert!(rep.top1 < qft::data::NUM_CLASSES);
                        seen.lock().unwrap().push(rep.id);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        done.store(true, Ordering::Relaxed);
        let churns = admin.join().unwrap();
        assert!(churns > 0, "the admin thread must actually interleave route changes");
    });

    let report = engine.shutdown();
    let want = (clients * per_client) as usize;
    let mut ids = seen.into_inner().unwrap();
    assert_eq!(ids.len(), want, "missing replies");
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), want, "duplicated replies");
    assert_eq!(report.requests as usize, want);
    // every request was charged to exactly one arm
    let ra = slot.version(1).unwrap().requests.get();
    let rb = slot.version(v2).unwrap().requests.get();
    assert_eq!((ra + rb) as usize, want, "arm counters must partition the traffic");
    // workers joined: nothing can still hold an in-flight reference
    assert_eq!(slot.in_flight(1), 0);
    assert_eq!(slot.in_flight(v2), 0);
    assert!(slot.route_changes.get() > 0);
}

#[test]
fn mid_stream_hot_swap_to_identical_twin_changes_no_reply_bits() {
    // swapping between bit-identical versions mid-stream must be invisible
    // in the replies, at 1 / 2 / 8 workers
    let fleet = load_lw();
    let slot = fleet.slot(0).unwrap().clone();
    let v2 = install_twin(&slot);
    let clients = 4u64;
    let per_client = 24u64;
    let hw = slot.arch.input_hw;
    let ch = slot.arch.input_ch;

    // offline per-image expectation from v1 (== v2: same params, same grid)
    let ds = Dataset::new(11);
    let v1 = slot.primary();
    let expected: Vec<Vec<u32>> = (0..clients * per_client)
        .map(|key| {
            let (img, _) = ds.sample(Split::Val, key);
            let x = Tensor::new(vec![1, hw, hw, ch], img);
            let logits = v1.model.forward_batch(&x, &mut Scratch::new(), qft::par::global());
            logits.data.iter().map(|v| v.to_bits()).collect()
        })
        .collect();
    drop(v1);

    for workers in [1usize, 2, 8] {
        slot.promote(1).unwrap();
        let cfg = ServeConfig {
            workers,
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        };
        let engine = Engine::start(fleet.clone(), &cfg);
        let done = AtomicBool::new(false);
        std::thread::scope(|s| {
            let slot_ref = &slot;
            let done_ref = &done;
            let admin = s.spawn(move || {
                let mut to_v2 = true;
                while !done_ref.load(Ordering::Relaxed) {
                    slot_ref.promote(if to_v2 { v2 } else { 1 }).unwrap();
                    to_v2 = !to_v2;
                    std::thread::sleep(Duration::from_micros(100));
                }
            });
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    let client = engine.client();
                    let expected = &expected;
                    s.spawn(move || {
                        let ds = Dataset::new(11);
                        for i in 0..per_client {
                            let key = c * per_client + i;
                            let (img, _) = ds.sample(Split::Val, key);
                            let rep = client
                                .infer_timeout(0, img, Duration::from_secs(60))
                                .expect("request dropped");
                            let got: Vec<u32> = rep.logits.iter().map(|v| v.to_bits()).collect();
                            assert_eq!(
                                expected[key as usize],
                                got,
                                "request {key} bits changed under swap ({workers} workers)"
                            );
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            done.store(true, Ordering::Relaxed);
            admin.join().unwrap();
        });
        engine.shutdown();
    }
}

#[test]
fn ab_arm_counts_converge_to_the_configured_weight() {
    let fleet = load_lw();
    let slot = fleet.slot(0).unwrap().clone();
    let v2 = install_twin(&slot);
    slot.set_ab(1, v2, 2_500).unwrap();
    let cfg = ServeConfig {
        workers: 3,
        max_batch: 4,
        max_wait: Duration::from_micros(100),
        ..Default::default()
    };
    let report = run_closed_loop(&fleet, &cfg, 4, 64, 0);
    assert_eq!(report.requests, 256);
    let ra = slot.version(1).unwrap().requests.get();
    let rb = slot.version(v2).unwrap().requests.get();
    assert_eq!(ra + rb, 256);
    // deficit routing bounds the deviation structurally, not as a
    // statistical tail: 25% of 256 = 64, give or take one stale-counter
    // micro-batch per concurrent worker (3 workers × max_batch 4)
    assert!((52..=76).contains(&rb), "secondary arm got {rb}/256 requests, want ~64 (25%)");
}

#[test]
fn shadow_capture_requantizes_and_hot_swaps_through_a_live_engine() {
    // the `repro requantize` loop, end-to-end: serve shadowed traffic,
    // rebuild deployment constants from the captured ranges, install the
    // result and promote it — all without stopping the engine
    let fleet = Fleet::load_with(
        Path::new("artifacts_nonexistent_for_test"),
        &[("synthetic".to_string(), BackendKind::Int(Mode::Lw))],
        FleetOptions { shadow_every: 1 },
    )
    .unwrap();
    let slot = fleet.slot(0).unwrap().clone();
    let engine = Engine::start(fleet.clone(), &ServeConfig::default());
    let client = engine.client();
    let ds = Dataset::new(5);
    for i in 0..16u64 {
        let (img, _) = ds.sample(Split::Val, i);
        client.infer(0, img).unwrap();
    }
    let ranges = slot.calib().expect("shadow_every attaches a recorder");
    assert!(!ranges.is_empty(), "shadow forwards must have captured ranges");
    let absmax = ranges.absmax();
    for v in &slot.arch.quantized_values {
        assert!(absmax.contains_key(v), "value {v} missing from capture");
    }
    let v2 = slot
        .install_requantized(&absmax, "requantized from live shadow capture".into())
        .unwrap();
    slot.promote(v2).unwrap();
    // the engine keeps serving, now on the requantized grid
    for i in 16..32u64 {
        let (img, _) = ds.sample(Split::Val, i);
        let rep = client.infer(0, img).unwrap();
        assert!(rep.logits.iter().all(|v| v.is_finite()));
        assert!(rep.top1 < qft::data::NUM_CLASSES);
    }
    let report = engine.shutdown();
    assert_eq!(report.requests, 32);
    assert_eq!(slot.primary().id, v2);
    let v2_batches = slot.version(v2).unwrap().batches.get();
    assert!(v2_batches > 0, "phase 2 must have executed on the requantized version");
    assert!(slot.status_table().contains("requantized"), "{}", slot.status_table());
}

#[test]
fn raw_submits_get_typed_rejections_and_workers_survive() {
    let fleet = load_lw();
    let want_len = fleet.slot(0).unwrap().image_len();
    let engine = Engine::start(fleet, &ServeConfig { workers: 2, ..Default::default() });
    let client = engine.client();

    // unknown slot: the worker answers instead of panicking or dropping
    let rx = client.submit_raw(7, vec![0.0; want_len]).unwrap();
    match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
        Err(Reject::UnknownSlot { slot: 7, slots: 1 }) => {}
        other => panic!("want UnknownSlot, got {other:?}"),
    }

    // short payload: per-request typed rejection
    let rx = client.submit_raw(0, vec![0.0; 3]).unwrap();
    match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
        Err(Reject::PayloadSize { slot: 0, got: 3, want }) => assert_eq!(want, want_len),
        other => panic!("want PayloadSize, got {other:?}"),
    }

    // the checked path rejects the same garbage at admission
    assert!(client.infer(7, vec![0.0; want_len]).is_err());
    assert!(client.infer(0, vec![0.0; 3]).is_err());

    // and the workers are still alive and serving
    let ds = Dataset::new(0);
    let (img, _) = ds.sample(Split::Val, 0);
    let rep = client.infer(0, img).unwrap();
    assert!(rep.top1 < qft::data::NUM_CLASSES);
    let report = engine.shutdown();
    // only the served request counts; rejects never reach a version arm
    assert_eq!(report.requests, 1);
}
