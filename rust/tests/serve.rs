//! Serving-path integration tests: batched-vs-single integer-forward parity
//! and batcher/engine correctness under contention.
//!
//! Everything here is hermetic — the built-in synthetic arch goes through
//! the same IR, trainable-init and deployment machinery as the manifest
//! archs, so no AOT artifacts are required.

use std::path::Path;
use std::sync::Mutex;
use std::time::Duration;

use qft::backend::BackendKind;
use qft::data::{Dataset, Split};
use qft::nn::{ArchSpec, ParamMap};
use qft::quant::deploy::{DeployScratch, DeployedModel, Mode};
use qft::serve::{synthetic_trainables, Engine, Fleet, ServeConfig};
use qft::Tensor;

fn trainables(mode: Mode, seed: u64) -> (ArchSpec, ParamMap) {
    synthetic_trainables(mode, seed)
}

#[test]
fn batched_integer_forward_matches_singles_bit_exactly() {
    for mode in [Mode::Lw, Mode::Dch] {
        let (arch, tm) = trainables(mode, 42);
        let model = DeployedModel::prepare(&arch, &tm, mode);
        let ds = Dataset::new(1);
        let n = 6;
        let (xb, _, _) = ds.batch(Split::Val, 0, n);
        let px = arch.input_hw * arch.input_hw * arch.input_ch;
        let nc = arch.num_classes;

        let mut scratch = DeployScratch::new();
        let lb = model.forward_batch(&xb, &mut scratch);
        assert_eq!(lb.shape, vec![n, nc]);

        for i in 0..n {
            let xi = Tensor::new(
                vec![1, arch.input_hw, arch.input_hw, arch.input_ch],
                xb.data[i * px..(i + 1) * px].to_vec(),
            );
            let li = model.forward_batch(&xi, &mut DeployScratch::new());
            assert_eq!(
                &lb.data[i * nc..(i + 1) * nc],
                &li.data[..],
                "{mode:?} image {i}: batched row != single-image logits"
            );
        }
    }
}

#[test]
fn batch_split_points_do_not_change_results() {
    // [6] vs [4]+[2] through the SAME scratch: grouping must not matter
    let (arch, tm) = trainables(Mode::Lw, 9);
    let model = DeployedModel::prepare(&arch, &tm, Mode::Lw);
    let ds = Dataset::new(2);
    let (xb, _, _) = ds.batch(Split::Val, 0, 6);
    let px = arch.input_hw * arch.input_hw * arch.input_ch;

    let mut scratch = DeployScratch::new();
    let all = model.forward_batch(&xb, &mut scratch);
    let first = Tensor::new(vec![4, 16, 16, 3], xb.data[..4 * px].to_vec());
    let second = Tensor::new(vec![2, 16, 16, 3], xb.data[4 * px..].to_vec());
    let l1 = model.forward_batch(&first, &mut scratch);
    let l2 = model.forward_batch(&second, &mut scratch);
    let mut joined = l1.data.clone();
    joined.extend_from_slice(&l2.data);
    assert_eq!(all.data, joined);
}

#[test]
fn dch_integer_deployment_is_bit_exact_with_fakequant_twin() {
    let (arch, tm) = trainables(Mode::Dch, 5);
    let ds = Dataset::new(3);
    let (x, _, _) = ds.batch(Split::Val, 0, 4);
    let (lf, ff) = qft::quant::deploy::forward_fakequant(&arch, &tm, Mode::Dch, &x);
    let model = DeployedModel::prepare(&arch, &tm, Mode::Dch);
    let (li, fi) = model.forward_batch_feat(&x, &mut DeployScratch::new());
    assert_eq!(lf.data, li.data);
    assert_eq!(ff.data, fi.data);
}

#[test]
fn engine_neither_drops_nor_duplicates_under_contention() {
    // tiny queue + many clients: backpressure, batching and reply routing
    // all under stress; every request must get exactly one reply
    let fleet = Fleet::load(
        Path::new("artifacts_nonexistent_for_test"),
        &[("synthetic".to_string(), BackendKind::Int(Mode::Lw))],
    )
    .unwrap();
    let cfg = ServeConfig {
        workers: 4,
        max_batch: 4,
        max_wait: Duration::from_micros(100),
        queue_cap: 8,
        ..Default::default()
    };
    let engine = Engine::start(fleet, &cfg);
    let clients = 8u64;
    let per_client = 40u64;
    let seen: Mutex<Vec<u64>> = Mutex::new(Vec::new());

    std::thread::scope(|s| {
        for c in 0..clients {
            let client = engine.client();
            let seen = &seen;
            s.spawn(move || {
                let ds = Dataset::new(c);
                for i in 0..per_client {
                    let (img, _) = ds.sample(Split::Val, i);
                    let rep = client
                        .infer_timeout(0, img, Duration::from_secs(60))
                        .expect("request dropped");
                    assert!(rep.batch_size >= 1 && rep.batch_size <= 4);
                    assert!(rep.top1 < qft::data::NUM_CLASSES);
                    seen.lock().unwrap().push(rep.id);
                }
            });
        }
    });

    let report = engine.shutdown();
    let want = (clients * per_client) as usize;
    let mut ids = seen.into_inner().unwrap();
    assert_eq!(ids.len(), want, "missing replies");
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), want, "duplicated replies");
    assert_eq!(report.requests as usize, want);
    assert!(report.batches as usize <= want);
    assert!(report.p50_us <= report.p99_us);
}

#[test]
fn serving_replies_match_offline_batched_forward() {
    // the engine must return exactly what the offline deployment path returns
    let fleet = Fleet::load(
        Path::new("artifacts_nonexistent_for_test"),
        &[("synthetic".to_string(), BackendKind::Int(Mode::Lw))],
    )
    .unwrap();
    let model_logits = {
        let ds = Dataset::new(0);
        let (x, _, _) = ds.batch(Split::Val, 0, 8);
        let mut scratch = qft::backend::Scratch::new();
        let v1 = fleet.slot(0).unwrap().primary();
        v1.model.forward_batch(&x, &mut scratch, qft::par::global())
    };
    let engine = Engine::start(fleet, &ServeConfig::default());
    let client = engine.client();
    let ds = Dataset::new(0);
    for i in 0..8usize {
        let (img, _) = ds.sample(Split::Val, i as u64);
        let rep = client.infer(0, img).unwrap();
        let nc = rep.logits.len();
        assert_eq!(
            rep.logits,
            model_logits.data[i * nc..(i + 1) * nc].to_vec(),
            "request {i}"
        );
    }
    engine.shutdown();
}

#[test]
fn adaptive_batching_does_not_change_replies() {
    // the pool-aware policy only moves the dispatch moment; per-image
    // logits must be identical with it on or off.  Concurrent clients make
    // the batcher actually assemble multi-request batches (a sequential
    // closed loop would pin every batch at size 1 and test nothing).
    let fleet = Fleet::load(
        Path::new("artifacts_nonexistent_for_test"),
        &[("synthetic".to_string(), BackendKind::Int(Mode::Lw))],
    )
    .unwrap();
    let clients = 6u64;
    let per_client = 16u64;
    let mut want: Vec<(u64, Vec<f32>)> = Vec::new();
    for adaptive in [true, false] {
        let cfg = ServeConfig { workers: 3, max_batch: 4, adaptive, ..Default::default() };
        let engine = Engine::start(fleet.clone(), &cfg);
        let seen: Mutex<Vec<(u64, Vec<f32>)>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for c in 0..clients {
                let client = engine.client();
                let seen = &seen;
                s.spawn(move || {
                    let ds = Dataset::new(7);
                    for i in 0..per_client {
                        let key = c * per_client + i;
                        let (img, _) = ds.sample(Split::Val, key);
                        let rep = client.infer(0, img).unwrap();
                        seen.lock().unwrap().push((key, rep.logits));
                    }
                });
            }
        });
        engine.shutdown();
        let mut got = seen.into_inner().unwrap();
        got.sort_by_key(|(key, _)| *key);
        if want.is_empty() {
            want = got;
        } else {
            assert_eq!(want, got, "adaptive batching changed reply contents");
        }
    }
}

#[test]
fn integer_eval_backend_runs_on_synthetic_arch() {
    let (arch, tm) = trainables(Mode::Lw, 0);
    let acc = qft::coordinator::eval::eval_backend(&arch, &tm, BackendKind::Int(Mode::Lw), 64, 0);
    assert!((0.0..=1.0).contains(&acc));
}
