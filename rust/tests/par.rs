//! `qft::par` parity tests: every parallel kernel must be bit-identical to
//! its serial twin at any thread count, in both deployment modes.
//!
//! This extends PR 1's batch-vs-single parity guarantee to parallelism:
//! parallel chunks own disjoint output row ranges and run the identical
//! serial inner loop, so per-element f32 accumulation order — and therefore
//! every bit of the result — is unchanged.  Hermetic: the built-in
//! synthetic arch needs no AOT artifacts.

use qft::par::{chunk_ranges, Pool};
use qft::quant::deploy::{DeployScratch, DeployedModel, Mode};
use qft::serve::synthetic_trainables;
use qft::tensor::conv::{conv2d, conv2d_par};
use qft::tensor::{matmul_slices, matmul_slices_par};
use qft::Tensor;

fn rand_tensor(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = qft::data::Rng::new(seed);
    let n: usize = shape.iter().product();
    Tensor::new(shape.to_vec(), (0..n).map(|_| rng.normal()).collect())
}

#[test]
fn parallel_matmul_is_bit_identical() {
    // odd sizes so chunk boundaries never line up with anything
    let (m, k, n) = (150usize, 33, 17);
    let x = rand_tensor(&[m, k], 1);
    let w = rand_tensor(&[k, n], 2);
    let mut serial = Vec::new();
    matmul_slices(&x.data, m, k, &w.data, n, &mut serial);
    for threads in [1usize, 2, 3, 8] {
        let pool = Pool::new(threads);
        let mut par = Vec::new();
        matmul_slices_par(&x.data, m, k, &w.data, n, &mut par, &pool);
        assert_eq!(serial, par, "{threads} threads");
    }
}

#[test]
fn parallel_conv_is_bit_identical() {
    // plain / strided / depthwise / grouped / even-kernel geometries
    let cases: &[(&[usize], &[usize], usize, usize)] = &[
        (&[2, 12, 12, 4], &[3, 3, 4, 8], 1, 1),
        (&[1, 16, 16, 3], &[3, 3, 3, 8], 2, 1),
        (&[2, 12, 12, 8], &[3, 3, 1, 8], 1, 8),
        (&[2, 12, 12, 8], &[3, 3, 4, 8], 1, 2),
        (&[1, 9, 9, 2], &[2, 2, 2, 4], 1, 1),
    ];
    for (i, (xs, ws, stride, groups)) in cases.iter().enumerate() {
        let x = rand_tensor(xs, 10 + i as u64);
        let w = rand_tensor(ws, 20 + i as u64);
        let bias: Vec<f32> = (0..ws[3]).map(|j| j as f32 * 0.1 - 0.2).collect();
        let want = conv2d(&x, &w, &bias, *stride, *groups);
        for threads in [1usize, 2, 8] {
            let pool = Pool::new(threads);
            let got = conv2d_par(&x, &w, &bias, *stride, *groups, &pool);
            assert_eq!(want.shape, got.shape, "case {i}, {threads} threads");
            assert_eq!(want.data, got.data, "case {i}, {threads} threads");
        }
    }
}

#[test]
fn pooled_forward_batch_is_bit_identical_both_modes() {
    for mode in [Mode::Lw, Mode::Dch] {
        let (arch, tm) = synthetic_trainables(mode, 7);
        let model = DeployedModel::prepare(&arch, &tm, mode);
        let ds = qft::data::Dataset::new(1);
        let (xb, _, _) = ds.batch(qft::data::Split::Val, 0, 6);
        let px = arch.input_hw * arch.input_hw * arch.input_ch;
        let single = Tensor::new(
            vec![1, arch.input_hw, arch.input_hw, arch.input_ch],
            xb.data[..px].to_vec(),
        );

        let mut serial_scratch = DeployScratch::new();
        let want = model.forward_batch(&xb, &mut serial_scratch);
        let want_single = model.forward_batch(&single, &mut serial_scratch);

        for threads in [1usize, 2, 8] {
            let pool = Pool::new(threads);
            let mut scratch = DeployScratch::new();
            // multi-image batch: batch-level parallelism
            let got = model.forward_batch_pooled(&xb, &mut scratch, &pool);
            assert_eq!(want.data, got.data, "{mode:?}, {threads} threads, cold");
            // warm scratch (reused child scratches) must stay identical
            let again = model.forward_batch_pooled(&xb, &mut scratch, &pool);
            assert_eq!(want.data, again.data, "{mode:?}, {threads} threads, warm");
            // single image: intra-op (output-row) conv parallelism
            let got1 = model.forward_batch_pooled(&single, &mut scratch, &pool);
            assert_eq!(want_single.data, got1.data, "{mode:?}, {threads} threads, single");
        }
    }
}

#[test]
fn pooled_forward_feat_is_bit_identical() {
    let (arch, tm) = synthetic_trainables(Mode::Lw, 3);
    let model = DeployedModel::prepare(&arch, &tm, Mode::Lw);
    let ds = qft::data::Dataset::new(4);
    let (xb, _, _) = ds.batch(qft::data::Split::Val, 0, 5);
    let (lw, fw) = model.forward_batch_feat(&xb, &mut DeployScratch::new());
    let pool = Pool::new(4);
    let (lp, fp) = model.forward_batch_feat_pooled(&xb, &mut DeployScratch::new(), &pool);
    assert_eq!(lw.data, lp.data);
    assert_eq!(fw.shape, fp.shape);
    assert_eq!(fw.data, fp.data);
}

#[test]
fn integer_eval_backend_is_thread_count_independent() {
    // the pooled eval path (process-wide pool, whatever width this machine
    // gives it) must agree with a hand-rolled serial accuracy loop
    let (arch, tm) = synthetic_trainables(Mode::Lw, 0);
    let model = DeployedModel::prepare(&arch, &tm, Mode::Lw);
    let ds = qft::data::Dataset::new(0);
    let n_images = 32;
    let b = arch.batch;
    let mut correct = 0usize;
    let mut scratch = DeployScratch::new();
    for i in 0..n_images / b {
        let (x, _, labels) = ds.batch(qft::data::Split::Val, (i * b) as u64, b);
        let preds = model.forward_batch(&x, &mut scratch).argmax_lastdim();
        correct += preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
    }
    let want = correct as f32 / n_images as f32;
    let got = qft::coordinator::eval::eval_backend(
        &arch,
        &tm,
        qft::backend::BackendKind::Int(Mode::Lw),
        n_images,
        0,
    );
    assert_eq!(want, got);
}

#[test]
fn chunk_ranges_are_deterministic_and_disjoint() {
    for (n, width) in [(256usize, 8usize), (1000, 3), (7, 16)] {
        let a = chunk_ranges(n, width, 1);
        let b = chunk_ranges(n, width, 1);
        assert_eq!(a, b, "chunking must depend on inputs only");
        let mut covered = 0;
        for r in &a {
            assert_eq!(r.start, covered);
            covered = r.end;
        }
        assert_eq!(covered, n);
    }
}
