//! Integration tests across the three layers: AOT artifacts executed through
//! PJRT, cross-checked against the pure-rust substrate.
//!
//! These require `make artifacts` to have run; they panic loudly (rather
//! than silently skipping) if artifacts are missing, because the integration
//! path IS the product.

use qft::coordinator::{eval, experiments, pretrain, qft as qft_stage, state};
use qft::data::{Dataset, Split};
use qft::nn::{fp_forward, ParamMap};
use qft::quant::deploy::{forward_fakequant, Mode};
use qft::runtime::Runtime;

fn runtime() -> Runtime {
    Runtime::load("artifacts").expect("artifacts missing — run `make artifacts`")
}

fn small_teacher(rt: &Runtime, arch: &str) -> ParamMap {
    // short pretrain (not the cached full teacher) to keep tests fast
    let cfg = pretrain::PretrainConfig { steps: 200, ..Default::default() };
    pretrain::pretrain(rt, arch, &cfg).unwrap().params
}

#[test]
fn fp_eval_hlo_matches_rust_forward() {
    let rt = runtime();
    let arch = rt.manifest.arch("resnet_tiny").unwrap().clone();
    let params = state::he_init_params(&arch, 3);
    let ds = Dataset::new(0);
    let (x, _, _) = ds.batch(Split::Val, 0, arch.batch);

    let mut inputs = params.to_ordered(&arch.params);
    inputs.push(x.clone());
    let out = rt.run("resnet_tiny", "fp_eval", &inputs).unwrap();

    let rust = fp_forward(&arch, &params, &x);
    let rel = out[0].sub(&rust.logits).norm() / rust.logits.norm().max(1e-6);
    assert!(rel < 1e-3, "HLO vs rust logits rel err {rel}");
}

#[test]
fn fp_stats_hlo_matches_rust_absmax() {
    let rt = runtime();
    let arch = rt.manifest.arch("convnet_tiny").unwrap().clone();
    let params = state::he_init_params(&arch, 4);
    let ds = Dataset::new(1);
    let (x, _, _) = ds.batch(Split::Calib, 0, arch.batch);

    let mut inputs = params.to_ordered(&arch.params);
    inputs.push(x.clone());
    let out = rt.run("convnet_tiny", "fp_stats", &inputs).unwrap();
    let rust = state::absmax_from_rust_forward(&arch, &params, &[x]);
    for (vid, t) in arch.quantized_values.iter().zip(&out) {
        let want = &rust[vid];
        for (a, b) in t.data.iter().zip(want) {
            assert!((a - b).abs() < 1e-3 * b.max(1e-3), "value {vid}: {a} vs {b}");
        }
    }
}

#[test]
fn q_eval_hlo_matches_rust_fakequant_sim() {
    let rt = runtime();
    for mode in [Mode::Lw, Mode::Dch] {
        let arch = rt.manifest.arch("convnet_tiny").unwrap().clone();
        let params = small_teacher(&rt, "convnet_tiny");
        let ds = Dataset::new(2);
        let batches = vec![ds.batch(Split::Calib, 0, arch.batch).0];
        let absmax = state::absmax_from_rust_forward(&arch, &params, &batches);
        let tm = state::init_trainables(
            &arch,
            &params,
            &absmax,
            mode,
            state::WeightScaleInit::Uniform,
            None,
        );
        let (x, _, _) = ds.batch(Split::Val, 0, arch.batch);
        let mut inputs = tm.to_ordered(arch.trainable_specs(mode.key()));
        inputs.push(x.clone());
        let out = rt
            .run("convnet_tiny", &format!("q_eval_{}", mode.key()), &inputs)
            .unwrap();
        let (logits, _) = forward_fakequant(&arch, &tm, mode, &x);
        let rel = out[0].sub(&logits).norm() / logits.norm().max(1e-6);
        assert!(rel < 5e-3, "{mode:?}: q_eval HLO vs rust sim rel err {rel}");
    }
}

#[test]
fn qft_fast_reduces_loss_and_beats_init() {
    let rt = runtime();
    let arch = "convnet_tiny";
    let teacher = small_teacher(&rt, arch);
    let mut cfg = qft_stage::QftConfig::fast(Mode::Lw);
    cfg.epochs = 3;
    cfg.calib_images = 128;
    cfg.images_per_epoch = 128;
    let r = qft_stage::run_qft(&rt, arch, &teacher, &cfg).unwrap();
    // compare window means: per-step KD loss is batch-noisy
    let k = 8.min(r.losses.len() / 2);
    let first: f32 = r.losses[..k].iter().sum::<f32>() / k as f32;
    let last: f32 = r.losses[r.losses.len() - k..].iter().sum::<f32>() / k as f32;
    assert!(last < first, "kd loss did not decrease: {first} -> {last}");

    // QFT accuracy >= init accuracy - small tolerance (it should recover)
    let acc_init = eval::eval_q(&rt, arch, &r.init, Mode::Lw, 256, 0).unwrap();
    let acc_qft = eval::eval_q(&rt, arch, &r.trainables, Mode::Lw, 256, 0).unwrap();
    assert!(
        acc_qft >= acc_init - 0.02,
        "QFT hurt accuracy: {acc_init} -> {acc_qft}"
    );
}

#[test]
fn frozen_scales_leave_scale_dof_untouched() {
    let rt = runtime();
    let arch_name = "convnet_tiny";
    let arch = rt.manifest.arch(arch_name).unwrap().clone();
    let teacher = small_teacher(&rt, arch_name);
    let mut cfg = qft_stage::QftConfig::fast(Mode::Lw);
    cfg.epochs = 1;
    cfg.calib_images = 64;
    cfg.images_per_epoch = 64;
    cfg.train_scales = false;
    let r = qft_stage::run_qft(&rt, arch_name, &teacher, &cfg).unwrap();
    for spec in arch.trainable_specs("lw") {
        let kind = spec.name.split(':').next().unwrap();
        if kind == "sv" || kind == "f" {
            assert_eq!(
                r.init.get(&spec.name).data,
                r.trainables.get(&spec.name).data,
                "{} moved despite frozen scales",
                spec.name
            );
        }
    }
    // weights DID move
    let w0 = &arch.trainable_specs("lw")[0].name;
    assert_ne!(r.init.get(w0).data, r.trainables.get(w0).data);
}

#[test]
fn teacher_cache_roundtrip() {
    let rt = runtime();
    let arch = rt.manifest.arch("regnet_tiny").unwrap().clone();
    let params = state::he_init_params(&arch, 9);
    let path = rt.dir().join("weights").join("__test_cache.qftw");
    qft::coordinator::weights_io::save(&path, &arch.params, &params).unwrap();
    let loaded = qft::coordinator::weights_io::load(&path).unwrap();
    for spec in &arch.params {
        assert_eq!(params.get(&spec.name), loaded.get(&spec.name));
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn experiments_fig3_hierarchy_holds_on_trained_teacher() {
    let rt = runtime();
    let rows = experiments::fig3(&rt, "mobilenet_tiny").unwrap();
    assert!(!rows.is_empty());
    for r in &rows {
        assert!(r.e_channelwise <= r.e_layerwise * 1.001, "{}", r.layer);
        assert!(r.e_dch <= r.e_channelwise * 1.05, "{}", r.layer);
    }
}
