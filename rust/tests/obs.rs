//! Concurrency tests for the `qft::obs` metric primitives and the serving
//! stats: N threads hammer one metric while a reader snapshots it, and no
//! recorded count may ever be lost or observed out of order.
//!
//! These tests share one process-global obs registry with each other, so
//! every test registers under its own unique key and none of them calls
//! `qft::obs::reset()` or flips the global enable switch.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use qft::obs::{self, BatchSpan, LogHistogram};
use qft::serve::ServeStats;

/// 8 writer threads × 10k records race one histogram while a reader takes
/// snapshots throughout: every snapshot must be internally consistent
/// (count == bucket sum == quantile mass) and monotone, and the final
/// snapshot must hold every single record.
#[test]
fn log_histogram_concurrent_recording_loses_nothing() {
    const WRITERS: u64 = 8;
    const PER_WRITER: u64 = 10_000;
    let h = Arc::new(LogHistogram::new());
    let stop = Arc::new(AtomicBool::new(false));

    let reader = {
        let h = h.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut last_count = 0u64;
            let mut iters = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let snap = h.snapshot();
                let bucket_sum: u64 = snap.buckets.iter().map(|&(_, _, c)| c).sum();
                assert_eq!(snap.count, bucket_sum, "count must equal the bucket mass");
                assert!(
                    snap.count >= last_count,
                    "count went backwards: {} -> {}",
                    last_count,
                    snap.count
                );
                if snap.count > 0 {
                    // quantiles must stay inside the observed value range
                    let p99 = snap.quantile(0.99);
                    assert!(snap.min <= p99 && p99 <= snap.max);
                }
                last_count = snap.count;
                iters += 1;
            }
            iters
        })
    };

    let writers: Vec<_> = (0..WRITERS)
        .map(|t| {
            let h = h.clone();
            std::thread::spawn(move || {
                for i in 0..PER_WRITER {
                    // values spread across many octaves so every shard's
                    // buckets get real traffic
                    h.record((i % 1000) + t);
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    let reader_iters = reader.join().unwrap();
    assert!(reader_iters > 0, "reader never observed the histogram");

    let snap = h.snapshot();
    assert_eq!(snap.count, WRITERS * PER_WRITER, "lost records");
    let expect_sum: u64 = (0..WRITERS)
        .map(|t| (0..PER_WRITER).map(|i| (i % 1000) + t).sum::<u64>())
        .sum();
    assert_eq!(snap.sum, expect_sum, "lost value mass");
    assert_eq!(snap.min, 0);
    assert_eq!(snap.max, 999 + WRITERS - 1);
}

/// Concurrent `record_span` calls through the global registry: request and
/// batch totals must both land exactly, per stage.
#[test]
fn stage_metrics_concurrent_spans_count_exactly() {
    const THREADS: u64 = 4;
    const SPANS: u64 = 250;
    const REQS_PER_SPAN: u64 = 3;
    let sm = obs::stage_metrics("obstest-conc/lw");
    let workers: Vec<_> = (0..THREADS)
        .map(|_| {
            let sm = sm.clone();
            std::thread::spawn(move || {
                for _ in 0..SPANS {
                    let t0 = Instant::now();
                    let span = BatchSpan {
                        formed: t0 + Duration::from_micros(10),
                        fwd_start: t0 + Duration::from_micros(20),
                        fwd_end: t0 + Duration::from_micros(120),
                        replied: t0 + Duration::from_micros(130),
                    };
                    sm.record_span(&span, (0..REQS_PER_SPAN).map(|_| t0));
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(sm.requests.get(), THREADS * SPANS * REQS_PER_SPAN);
    assert_eq!(sm.batches.get(), THREADS * SPANS);
    assert_eq!(sm.queue_wait_us.snapshot().count, THREADS * SPANS * REQS_PER_SPAN);
    assert_eq!(sm.compute_us.snapshot().count, THREADS * SPANS);
    // the registry hands back the same cells on re-lookup
    assert_eq!(obs::stage_metrics("obstest-conc/lw").batches.get(), THREADS * SPANS);
}

/// The exposition renderers must stay valid while recorders are racing
/// them: render + validate the Prometheus text and round-trip the JSON
/// under active concurrent writes.
#[test]
fn exposition_stays_valid_under_concurrent_recording() {
    let sm = obs::stage_metrics("obstest-expo/dch");
    let no = obs::net_obs("obstest-expo/dch", &["conv0".to_string(), "fc".to_string()]);
    let stop = Arc::new(AtomicBool::new(false));
    let writer = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut n = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let t0 = Instant::now();
                let span = BatchSpan { formed: t0, fwd_start: t0, fwd_end: t0, replied: t0 };
                sm.record_span(&span, [t0]);
                no.passes.add(1);
                no.layers[0].add_phase_ns(obs::Phase::Gemm, 100);
                no.layers[0].add_total_ns(150);
                n += 1;
            }
            n
        })
    };
    for _ in 0..50 {
        let prom = obs::render_prometheus();
        obs::validate_prometheus(&prom).expect("live exposition must stay well-formed");
        let snap = obs::snapshot();
        let back = obs::Snapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back.stage_for("obstest-expo/dch"), snap.stage_for("obstest-expo/dch"));
    }
    stop.store(true, Ordering::Relaxed);
    let written = writer.join().unwrap();
    assert!(written > 0);
    let text = obs::render_prometheus();
    assert!(text.contains("model=\"obstest-expo/dch\""), "key missing from exposition");
}

/// N threads hammer one `ServeStats` with `record_batch` while a reader
/// polls `report()`: totals must be monotone and nothing may be lost.
#[test]
fn serve_stats_concurrent_batches_count_exactly() {
    const THREADS: u64 = 8;
    const BATCHES: u64 = 400;
    let stats = Arc::new(ServeStats::with_pool(2));
    let stop = Arc::new(AtomicBool::new(false));
    let reader = {
        let stats = stats.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut last = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let r = stats.report();
                assert!(r.requests >= last, "requests went backwards");
                assert_eq!(r.requests, r.batches * 2, "2 requests per batch, always");
                last = r.requests;
            }
        })
    };
    let writers: Vec<_> = (0..THREADS)
        .map(|t| {
            let stats = stats.clone();
            std::thread::spawn(move || {
                let completion =
                    [Duration::from_micros(100 + t), Duration::from_micros(200 + t)];
                let replied =
                    [Duration::from_micros(110 + t), Duration::from_micros(210 + t)];
                for _ in 0..BATCHES {
                    stats.record_batch(2, &completion, &replied);
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    reader.join().unwrap();

    let r = stats.report();
    assert_eq!(r.batches, THREADS * BATCHES);
    assert_eq!(r.requests, THREADS * BATCHES * 2);
    // every completion latency lies in [100, 200 + THREADS); the quantiles
    // must too, and reply-inclusive must sit 10µs above completion
    assert!(r.p50_us >= 100 && r.p50_us < 200 + THREADS);
    assert!(r.reply_p50_us >= 110 && r.reply_p50_us < 210 + THREADS);
    assert_eq!(r.max_us, 200 + THREADS - 1);
    assert_eq!(r.reply_max_us, 210 + THREADS - 1);
}
