//! Backend parity suite: every [`BackendKind`] behind the unified
//! `qft::backend` trait must produce bit-identical results to its
//! pre-refactor twin (the free functions it re-homed), at 1/2/8 threads —
//! plus the `lw-i8` agreement, batch-invariance and NaN/Inf masking
//! contracts for the new integer engine, and the W4-vs-i8 panel equality
//! contract (nibble packing is a pure storage change: forced-on vs
//! forced-off must be bit-identical at every thread count, poison
//! included).
//!
//! Everything is hermetic (built-in synthetic arch, no AOT artifacts).
//! CI reruns the suite under forced `QFT_KERNEL=scalar` / `=avx2` legs,
//! which exercises the auto W4 selection under each dispatch path.

use std::path::Path;
use std::time::Duration;

use qft::backend::{self, Backend, BackendKind, Int8Backend, Scratch};
use qft::coordinator::state;
use qft::data::{Dataset, Split};
use qft::nn::fp_forward;
use qft::par::Pool;
use qft::quant::deploy::{forward_fakequant, DeployScratch, DeployedModel, Mode};
use qft::serve::{synthetic_arch, synthetic_trainables, Engine, Fleet, ServeConfig};
use qft::Tensor;

const THREADS: &[usize] = &[1, 2, 8];

fn bits(t: &Tensor) -> Vec<u32> {
    t.data.iter().map(|v| v.to_bits()).collect()
}

fn val_batch(n: usize, seed: u64) -> Tensor {
    Dataset::new(seed).batch(Split::Val, 0, n).0
}

#[test]
fn fp_backend_is_bit_identical_to_fp_forward() {
    let arch = synthetic_arch();
    let params = state::he_init_params(&arch, 11);
    let x = val_batch(5, 4);
    let want = fp_forward(&arch, &params, &x);
    let net = backend::prepare(BackendKind::Fp, &arch, &params);
    for &t in THREADS {
        let pool = Pool::new(t);
        let mut scratch = Scratch::new();
        let (logits, feat) = net.forward_batch_feat(&x, &mut scratch, &pool);
        assert_eq!(bits(&want.logits), bits(&logits), "fp logits, {t} threads");
        assert_eq!(bits(&want.feat), bits(&feat), "fp feat, {t} threads");
        assert_eq!(bits(&logits), bits(&net.forward_batch(&x, &mut scratch, &pool)));
    }
}

#[test]
fn fakequant_backend_is_bit_identical_to_forward_fakequant() {
    for mode in [Mode::Lw, Mode::Dch] {
        let (arch, tm) = synthetic_trainables(mode, 7);
        let x = val_batch(5, 5);
        let (wl, wf) = forward_fakequant(&arch, &tm, mode, &x);
        let net = backend::prepare(BackendKind::FakeQuant(mode), &arch, &tm);
        assert_eq!(net.kind(), BackendKind::FakeQuant(mode));
        for &t in THREADS {
            let pool = Pool::new(t);
            let mut scratch = Scratch::new();
            let (logits, feat) = net.forward_batch_feat(&x, &mut scratch, &pool);
            assert_eq!(bits(&wl), bits(&logits), "{mode:?} logits, {t} threads");
            assert_eq!(bits(&wf), bits(&feat), "{mode:?} feat, {t} threads");
        }
    }
}

#[test]
fn int_backend_is_bit_identical_to_pre_refactor_integer_path() {
    for mode in [Mode::Lw, Mode::Dch] {
        let (arch, tm) = synthetic_trainables(mode, 42);
        let x = val_batch(6, 1);
        // the pre-refactor twin the serving/eval paths used to drive directly
        let deployed = DeployedModel::prepare(&arch, &tm, mode);
        let want = deployed.forward_batch(&x, &mut DeployScratch::new());
        let (wl_feat, wf) = deployed.forward_batch_feat(&x, &mut DeployScratch::new());
        assert_eq!(bits(&want), bits(&wl_feat));
        let net = backend::prepare(BackendKind::Int(mode), &arch, &tm);
        for &t in THREADS {
            let pool = Pool::new(t);
            let mut scratch = Scratch::new();
            let got = net.forward_batch(&x, &mut scratch, &pool);
            assert_eq!(bits(&want), bits(&got), "{mode:?} logits, {t} threads");
            // warm-scratch rerun must not drift
            let again = net.forward_batch(&x, &mut scratch, &pool);
            assert_eq!(bits(&got), bits(&again), "{mode:?} warm rerun, {t} threads");
            let (_, feat) = net.forward_batch_feat(&x, &mut scratch, &pool);
            assert_eq!(bits(&wf), bits(&feat), "{mode:?} feat, {t} threads");
        }
    }
}

#[test]
fn int8_backend_tracks_int_lw_and_is_thread_invariant() {
    let (arch, tm) = synthetic_trainables(Mode::Lw, 3);
    let x = val_batch(8, 9);
    let int_net = backend::prepare(BackendKind::Int(Mode::Lw), &arch, &tm);
    let i8_net = backend::prepare(BackendKind::Int8, &arch, &tm);
    assert_eq!(i8_net.kind(), BackendKind::Int8);
    assert_eq!(i8_net.image_len(), int_net.image_len());

    let serial = Pool::new(1);
    let want = int_net.forward_batch(&x, &mut Scratch::new(), &serial);
    let base = i8_net.forward_batch(&x, &mut Scratch::new(), &serial);

    // logits agreement: the i32 accumulator computes the exact integer sum
    // the f32 path computes (exactly, at these magnitudes), so the grids
    // must agree tightly — and must rank identically
    for (i, (a, b)) in want.data.iter().zip(&base.data).enumerate() {
        assert!(a.is_finite() && b.is_finite(), "logit {i}: {a} vs {b}");
        let tol = 1e-4 * a.abs().max(b.abs()).max(1.0);
        assert!((a - b).abs() <= tol, "logit {i}: lw {a} vs lw-i8 {b}");
    }
    assert_eq!(want.argmax_lastdim(), base.argmax_lastdim());

    // thread invariance: the i8 batch-parallel path is bit-identical to its
    // serial twin, warm or cold
    for &t in THREADS {
        let pool = Pool::new(t);
        let mut scratch = Scratch::new();
        let got = i8_net.forward_batch(&x, &mut scratch, &pool);
        assert_eq!(bits(&base), bits(&got), "lw-i8 {t} threads");
        let again = i8_net.forward_batch(&x, &mut scratch, &pool);
        assert_eq!(bits(&base), bits(&again), "lw-i8 warm rerun, {t} threads");
        let (logits, feat) = i8_net.forward_batch_feat(&x, &mut scratch, &pool);
        assert_eq!(bits(&base), bits(&logits), "lw-i8 feat-path logits, {t} threads");
        assert_eq!(feat.shape[3], arch.feat_channels);
        assert!(feat.data.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn int8_single_image_intra_op_is_bit_identical_across_threads() {
    // batch = 1: the pooled path must dispatch to intra-op (output-row)
    // parallelism inside each conv/fc GEMM — and stay bit-identical to the
    // fully serial walk at every thread count, warm or cold (integer
    // accumulation is exact and the row chunks own disjoint accumulators)
    let (arch, tm) = synthetic_trainables(Mode::Lw, 17);
    let net = backend::prepare(BackendKind::Int8, &arch, &tm);
    let x = val_batch(1, 3);
    let want = net.forward_batch(&x, &mut Scratch::new(), &Pool::new(1));
    for &t in THREADS {
        let pool = Pool::new(t);
        let mut scratch = Scratch::new();
        let got = net.forward_batch(&x, &mut scratch, &pool);
        assert_eq!(bits(&want), bits(&got), "lw-i8 single image, {t} threads");
        let again = net.forward_batch(&x, &mut scratch, &pool);
        assert_eq!(bits(&want), bits(&again), "lw-i8 single image warm, {t} threads");
        let (logits, feat) = net.forward_batch_feat(&x, &mut scratch, &pool);
        assert_eq!(bits(&want), bits(&logits), "lw-i8 single image feat path, {t} threads");
        assert!(feat.data.iter().all(|v| v.is_finite()));
    }
    // and the f32 integer twin keeps agreeing on the single-image path
    let lw = backend::prepare(BackendKind::Int(Mode::Lw), &arch, &tm);
    let lw_logits = lw.forward_batch(&x, &mut Scratch::new(), &Pool::new(8));
    for (i, (a, b)) in lw_logits.data.iter().zip(&want.data).enumerate() {
        let tol = 1e-4 * a.abs().max(b.abs()).max(1.0);
        assert!((a - b).abs() <= tol, "logit {i}: lw {a} vs lw-i8 {b}");
    }
}

#[test]
fn int8_batch_split_points_do_not_change_results() {
    let (arch, tm) = synthetic_trainables(Mode::Lw, 6);
    let net = backend::prepare(BackendKind::Int8, &arch, &tm);
    let pool = Pool::new(1);
    let x = val_batch(6, 2);
    let px = net.image_len();
    let mut scratch = Scratch::new();
    let all = net.forward_batch(&x, &mut scratch, &pool);
    let nc = net.num_classes();
    for i in 0..6 {
        let xi = Tensor::new(
            vec![1, arch.input_hw, arch.input_hw, arch.input_ch],
            x.data[i * px..(i + 1) * px].to_vec(),
        );
        let li = net.forward_batch(&xi, &mut scratch, &pool);
        assert_eq!(
            &all.data[i * nc..(i + 1) * nc],
            &li.data[..],
            "image {i}: batched row != single-image logits"
        );
    }
}

#[test]
fn zero_code_activations_mask_nonfinite_weights_in_both_integer_engines() {
    // poison every w:conv0 tap that reads input channel 1 (NaN and ±inf),
    // then feed inputs whose channel 1 is all-zero: ±inf clamps to the
    // saturated codes ±7 on both grids, NaN survives into the f32 codes
    // (masked by the kernel's zero-activation skip) but casts to the zero
    // code on the i8 grid — with zero activations every poisoned tap
    // contributes nothing either way, so both backends must yield finite,
    // mutually consistent logits
    let (arch, mut tm) = synthetic_trainables(Mode::Lw, 12);
    {
        let w = tm.get_mut("w:conv0");
        let (cin, cout) = (w.shape[2], w.shape[3]);
        assert_eq!(cin, 3);
        for (idx, v) in w.data.iter_mut().enumerate() {
            if (idx / cout) % cin == 1 {
                *v = match idx % 3 {
                    0 => f32::NAN,
                    1 => f32::INFINITY,
                    _ => f32::NEG_INFINITY,
                };
            }
        }
    }
    let mut x = val_batch(4, 8);
    let c = *x.shape.last().unwrap();
    for (i, v) in x.data.iter_mut().enumerate() {
        if i % c == 1 {
            *v = 0.0;
        }
    }
    let pool = Pool::new(2);
    let int_net = backend::prepare(BackendKind::Int(Mode::Lw), &arch, &tm);
    let i8_net = backend::prepare(BackendKind::Int8, &arch, &tm);
    let li = int_net.forward_batch(&x, &mut Scratch::new(), &pool);
    let l8 = i8_net.forward_batch(&x, &mut Scratch::new(), &pool);
    assert!(li.data.iter().all(|v| v.is_finite()), "lw logits poisoned: {:?}", li.data);
    assert!(l8.data.iter().all(|v| v.is_finite()), "lw-i8 logits poisoned: {:?}", l8.data);
    for (i, (a, b)) in li.data.iter().zip(&l8.data).enumerate() {
        let tol = 1e-4 * a.abs().max(b.abs()).max(1.0);
        assert!((a - b).abs() <= tol, "logit {i}: lw {a} vs lw-i8 {b}");
    }
}

#[test]
fn int8_w4_panels_are_bit_identical_to_i8_panels() {
    // nibble packing is a pure storage change — same codes, same exact
    // integer arithmetic — so forcing the W4 panels on vs off must agree
    // to the BIT, at every thread count, warm or cold, on both forward
    // entry points
    let (arch, tm) = synthetic_trainables(Mode::Lw, 21);
    let i8_net = Int8Backend::with_w4(false).prepare(&arch, &tm);
    let w4_net = Int8Backend::with_w4(true).prepare(&arch, &tm);
    let x = val_batch(5, 14);
    let want = i8_net.forward_batch(&x, &mut Scratch::new(), &Pool::new(1));
    for &t in THREADS {
        let pool = Pool::new(t);
        let mut scratch = Scratch::new();
        let got = w4_net.forward_batch(&x, &mut scratch, &pool);
        assert_eq!(bits(&want), bits(&got), "W4 vs i8 panels, {t} threads");
        let again = w4_net.forward_batch(&x, &mut scratch, &pool);
        assert_eq!(bits(&want), bits(&again), "W4 warm rerun, {t} threads");
        let (logits, feat) = w4_net.forward_batch_feat(&x, &mut scratch, &pool);
        assert_eq!(bits(&want), bits(&logits), "W4 feat-path logits, {t} threads");
        assert!(feat.data.iter().all(|v| v.is_finite()));
    }
    // the default backend (auto selection) must match both, whichever
    // panel store it picked for this host/env
    let auto = backend::prepare(BackendKind::Int8, &arch, &tm)
        .forward_batch(&x, &mut Scratch::new(), &Pool::new(1));
    assert_eq!(bits(&want), bits(&auto), "auto panel selection drifted");
}

#[test]
fn int8_w4_single_image_intra_op_is_bit_identical_across_threads() {
    // batch = 1 through the W4 panels: the intra-op (output-row) split
    // must stay bit-identical to the serial walk at every thread count
    let (arch, tm) = synthetic_trainables(Mode::Lw, 17);
    let net = Int8Backend::with_w4(true).prepare(&arch, &tm);
    let x = val_batch(1, 3);
    let want = net.forward_batch(&x, &mut Scratch::new(), &Pool::new(1));
    for &t in THREADS {
        let pool = Pool::new(t);
        let mut scratch = Scratch::new();
        let got = net.forward_batch(&x, &mut scratch, &pool);
        assert_eq!(bits(&want), bits(&got), "W4 single image, {t} threads");
        let again = net.forward_batch(&x, &mut scratch, &pool);
        assert_eq!(bits(&want), bits(&again), "W4 single image warm, {t} threads");
    }
}

#[test]
fn zero_code_activations_mask_nonfinite_weights_through_w4_panels() {
    // the same poison pattern as the i8-panel masking test above, forced
    // through the nibble-packed panels at 1/2/8 threads: NaN casts to the
    // zero code and ±inf saturates to ±7 — both inside the W4 nibble
    // range — and the all-zero activation codes contribute nothing, so
    // W4 logits must be finite and bit-identical to the i8 panels'
    let (arch, mut tm) = synthetic_trainables(Mode::Lw, 12);
    {
        let w = tm.get_mut("w:conv0");
        let (cin, cout) = (w.shape[2], w.shape[3]);
        assert_eq!(cin, 3);
        for (idx, v) in w.data.iter_mut().enumerate() {
            if (idx / cout) % cin == 1 {
                *v = match idx % 3 {
                    0 => f32::NAN,
                    1 => f32::INFINITY,
                    _ => f32::NEG_INFINITY,
                };
            }
        }
    }
    let mut x = val_batch(4, 8);
    let c = *x.shape.last().unwrap();
    for (i, v) in x.data.iter_mut().enumerate() {
        if i % c == 1 {
            *v = 0.0;
        }
    }
    let i8_net = Int8Backend::with_w4(false).prepare(&arch, &tm);
    let w4_net = Int8Backend::with_w4(true).prepare(&arch, &tm);
    let want = i8_net.forward_batch(&x, &mut Scratch::new(), &Pool::new(1));
    assert!(want.data.iter().all(|v| v.is_finite()), "i8 logits poisoned: {:?}", want.data);
    for &t in THREADS {
        let got = w4_net.forward_batch(&x, &mut Scratch::new(), &Pool::new(t));
        assert!(got.data.iter().all(|v| v.is_finite()), "W4 logits poisoned at {t} threads");
        assert_eq!(bits(&want), bits(&got), "W4 vs i8 under poison, {t} threads");
    }
}

#[test]
fn engine_serves_lw_i8_end_to_end() {
    // the acceptance path behind `repro serve --backend lw-i8`: fleet →
    // engine → replies, and replies equal the offline i8 forward
    let fleet = Fleet::load(
        Path::new("artifacts_nonexistent_for_test"),
        &[("synthetic".to_string(), BackendKind::Int8)],
    )
    .unwrap();
    assert_eq!(fleet.resolve("synthetic/lw-i8"), Some(0));
    let offline = {
        let x = val_batch(8, 0);
        let v1 = fleet.slot(0).unwrap().primary();
        v1.model.forward_batch(&x, &mut Scratch::new(), qft::par::global())
    };
    let engine = Engine::start(
        fleet,
        &ServeConfig {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        },
    );
    let client = engine.client();
    let ds = Dataset::new(0);
    for i in 0..8usize {
        let (img, _) = ds.sample(Split::Val, i as u64);
        let rep = client.infer(0, img).unwrap();
        let nc = rep.logits.len();
        assert_eq!(
            rep.logits,
            offline.data[i * nc..(i + 1) * nc].to_vec(),
            "request {i}"
        );
    }
    engine.shutdown();
}

#[test]
fn backend_keys_round_trip_and_reject_drift() {
    for kind in BackendKind::ALL {
        assert_eq!(BackendKind::from_key(kind.key()).unwrap(), kind);
    }
    for bad in ["LW", "DCH", "fq-LW", "FP", "lw-I8", "int", ""] {
        assert!(BackendKind::from_key(bad).is_err(), "{bad:?} must not parse");
    }
    assert!(Mode::from_key("LW").is_err());
    assert_eq!(Mode::from_key("dch").unwrap(), Mode::Dch);
}

#[test]
fn eval_backend_covers_every_kind() {
    let arch = synthetic_arch();
    for kind in BackendKind::ALL {
        let acc = match kind.mode() {
            Some(mode) => {
                let (arch, tm) = synthetic_trainables(mode, 0);
                qft::coordinator::eval::eval_backend(&arch, &tm, kind, 32, 0)
            }
            None => {
                let params = state::he_init_params(&arch, 0);
                qft::coordinator::eval::eval_backend(&arch, &params, kind, 32, 0)
            }
        };
        assert!((0.0..=1.0).contains(&acc), "{}: {acc}", kind.key());
    }
}
