//! Wire-protocol and TCP front-end integration tests: frame-codec totality
//! (round-trip + adversarial inputs), loopback bit-exactness against the
//! in-process forward path, typed error replies for malformed traffic,
//! admission-control shedding under overload, and bounded graceful drain.
//!
//! Hermetic — every engine test runs on the built-in synthetic arch, binds
//! an ephemeral loopback port, and needs no AOT artifacts.  Engine tests
//! serialize on one mutex because [`qft::obs`] metrics are process-global
//! (the queue-depth gauge and net counters would otherwise cross-talk).

use std::net::TcpStream;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex, MutexGuard};
use std::time::Duration;

use qft::backend::{self, BackendKind, PreparedNet, Scratch};
use qft::data::{Dataset, Rng, Split, NUM_CLASSES};
use qft::net::frame::{
    self, HEADER_LEN, MAGIC, MAX_PAYLOAD, TY_ERROR, TY_INFER, TY_REPLY, TY_STATS_ACK,
    TY_STATS_DELTA, TY_STATS_PULL,
};
use qft::net::{ErrCode, Frame, FrameError, NetConfig, NetServer};
use qft::par::Pool;
use qft::quant::deploy::Mode;
use qft::serve::{Engine, Fleet, Reject, ServeConfig};
use qft::Tensor;

/// Engine tests share the process-global obs registry — run them one at a
/// time so gauge/counter assertions see only their own traffic.
fn obs_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn load_lw() -> std::sync::Arc<Fleet> {
    Fleet::load(
        Path::new("artifacts_nonexistent_for_test"),
        &[("synthetic".to_string(), BackendKind::Int(Mode::Lw))],
    )
    .unwrap()
}

// ------------------------------------------------------------ frame codec

const ALL_CODES: [ErrCode; 10] = [
    ErrCode::UnknownSlot,
    ErrCode::PayloadSize,
    ErrCode::Busy,
    ErrCode::Shutdown,
    ErrCode::BadMagic,
    ErrCode::BadVersion,
    ErrCode::Oversized,
    ErrCode::Truncated,
    ErrCode::Malformed,
    ErrCode::Internal,
];

fn ascii(rng: &mut Rng, max_len: usize) -> String {
    let n = (rng.next_u64() as usize) % (max_len + 1);
    (0..n).map(|_| (b'a' + (rng.next_u64() % 26) as u8) as char).collect()
}

fn random_frame(rng: &mut Rng, case: usize) -> Frame {
    let id = rng.next_u64();
    match case % 3 {
        0 => Frame::Infer {
            id,
            slot_key: ascii(rng, 32),
            image: (0..(rng.next_u64() % 64)).map(|_| rng.uniform() * 2.0 - 1.0).collect(),
        },
        1 => Frame::Reply {
            id,
            top1: rng.next_u64() as u16,
            batch: rng.next_u64() as u16,
            latency_us: rng.next_u64() as u32,
            logits: (0..(rng.next_u64() % 64)).map(|_| rng.uniform() * 10.0).collect(),
        },
        _ => Frame::Error {
            id,
            code: ALL_CODES[case % ALL_CODES.len()],
            msg: ascii(rng, 48),
        },
    }
}

#[test]
fn frame_codec_round_trips_random_frames() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..300 {
        let f = random_frame(&mut rng, case);
        let bytes = f.encode();
        let (back, used) = frame::decode(&bytes).expect("round trip decodes");
        assert_eq!(used, bytes.len(), "case {case}: consumed length");
        assert_eq!(back, f, "case {case}: round-trip identity");
        // a second frame behind the first is the next decode's business
        let mut two = bytes.clone();
        two.extend_from_slice(&bytes);
        let (first, used1) = frame::decode(&two).unwrap();
        assert_eq!((first, used1), (f.clone(), bytes.len()));
        let (second, used2) = frame::decode(&two[used1..]).unwrap();
        assert_eq!((second, used2), (f, bytes.len()));
    }
}

#[test]
fn truncated_frames_are_rejected_typed_never_panicking() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..12 {
        let bytes = random_frame(&mut rng, case).encode();
        for cut in 0..bytes.len() {
            match frame::decode(&bytes[..cut]) {
                Err(FrameError::Truncated { want, got }) => {
                    assert_eq!(got, cut, "case {case} cut {cut}");
                    assert!(want > got, "case {case} cut {cut}: want {want} <= got {got}");
                }
                other => panic!("case {case} cut {cut}: expected Truncated, got {other:?}"),
            }
        }
    }
}

/// Build a raw header + payload by hand (to craft what `encode` refuses to).
fn raw(ty: u8, version: u8, len: u32, payload: &[u8]) -> Vec<u8> {
    let mut b = Vec::with_capacity(HEADER_LEN + payload.len());
    b.extend_from_slice(&MAGIC);
    b.push(version);
    b.push(ty);
    b.extend_from_slice(&[0, 0]);
    b.extend_from_slice(&7u64.to_le_bytes());
    b.extend_from_slice(&len.to_le_bytes());
    b.extend_from_slice(payload);
    b
}

#[test]
fn malformed_frames_get_typed_errors() {
    // bad magic wins over everything else
    let mut b = Frame::Error { id: 1, code: ErrCode::Busy, msg: "x".into() }.encode();
    b[0] = b'X';
    assert!(matches!(frame::decode(&b), Err(FrameError::BadMagic(_))));
    // wrong version
    let b = raw(TY_INFER, 9, 0, &[]);
    assert_eq!(frame::decode(&b).unwrap_err(), FrameError::BadVersion(9));
    // unknown frame type
    let b = raw(42, frame::VERSION, 0, &[]);
    assert_eq!(frame::decode(&b).unwrap_err(), FrameError::BadType(42));
    // a lying length prefix is rejected before any allocation
    let b = raw(TY_INFER, frame::VERSION, (MAX_PAYLOAD + 1) as u32, &[]);
    assert!(matches!(frame::decode(&b), Err(FrameError::Oversized { .. })));
    // slot key runs past the payload
    let p = [10u8, 0, b'a', b'b', b'c'];
    let b = raw(TY_INFER, frame::VERSION, p.len() as u32, &p);
    assert!(matches!(frame::decode(&b), Err(FrameError::Malformed(_))));
    // image region not a multiple of 4 bytes
    let p = [1u8, 0, b'a', 0, 0, 0];
    let b = raw(TY_INFER, frame::VERSION, p.len() as u32, &p);
    assert!(matches!(frame::decode(&b), Err(FrameError::Malformed(_))));
    // error frame with an unknown error code
    let p = [0xFFu8, 0xFF];
    let b = raw(TY_ERROR, frame::VERSION, p.len() as u32, &p);
    assert!(matches!(frame::decode(&b), Err(FrameError::Malformed(_))));
    // fuzz: decode is total over arbitrary garbage — typed error or frame,
    // never a panic
    let mut rng = Rng::new(0xDEAD);
    for _ in 0..2000 {
        let n = (rng.next_u64() % 96) as usize;
        let buf: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        let _ = frame::decode(&buf);
    }
    // and so is decode_payload per registered type (stats frames included)
    for ty in [TY_INFER, TY_REPLY, TY_ERROR, TY_STATS_PULL, TY_STATS_DELTA, TY_STATS_ACK] {
        assert!(frame::frame_kind(ty).is_some(), "type {ty} missing from the registry");
        for _ in 0..500 {
            let n = (rng.next_u64() % 64) as usize;
            let p: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            let _ = frame::decode_payload(ty, 0, &p);
        }
    }
}

// -------------------------------------------------------------- loopback

#[test]
fn loopback_replies_are_bit_identical_to_in_process_forward() {
    let _g = obs_lock();
    let fleet = Fleet::load(
        Path::new("artifacts_nonexistent_for_test"),
        &[
            ("synthetic".to_string(), BackendKind::Int(Mode::Lw)),
            ("synthetic".to_string(), BackendKind::Int8),
            ("synthetic".to_string(), BackendKind::Fp),
        ],
    )
    .unwrap();
    // ground truth: the frozen grid's single-image forward, in process
    let per_slot: Vec<(String, Vec<Vec<f32>>)> = (0..fleet.len())
        .map(|sid| {
            let slot = fleet.slot(sid).unwrap();
            let v1 = slot.primary();
            let (hw, ch) = (slot.arch.input_hw, slot.arch.input_ch);
            let ds = Dataset::new(11);
            let rows = (0..12u64)
                .map(|i| {
                    let (img, _) = ds.sample(Split::Val, i);
                    let x = Tensor::new(vec![1, hw, hw, ch], img);
                    v1.model.forward_batch(&x, &mut Scratch::new(), qft::par::global()).data
                })
                .collect();
            (slot.key.clone(), rows)
        })
        .collect();

    for workers in [1usize, 2, 8] {
        let engine = Engine::start(fleet.clone(), &ServeConfig { workers, ..Default::default() });
        let server = NetServer::start(engine, &NetConfig::default()).unwrap();
        let addr = server.local_addr();
        std::thread::scope(|s| {
            for c in 0..4u64 {
                let per_slot = &per_slot;
                s.spawn(move || {
                    let mut stream = TcpStream::connect(addr).unwrap();
                    stream.set_nodelay(true).unwrap();
                    let ds = Dataset::new(11);
                    for (key, rows) in per_slot {
                        for i in 0..12u64 {
                            let (img, _) = ds.sample(Split::Val, i);
                            let id = c * 1000 + i;
                            let req = Frame::Infer { id, slot_key: key.clone(), image: img };
                            frame::write_frame(&mut stream, &req).unwrap();
                            match frame::read_frame(&mut stream).unwrap() {
                                Frame::Reply { id: rid, top1, logits, .. } => {
                                    assert_eq!(rid, id, "{key}: reply id echo");
                                    assert_eq!(
                                        logits, rows[i as usize],
                                        "{key} image {i} at {workers} workers: \
                                         wire logits != in-process forward"
                                    );
                                    assert!((top1 as usize) < NUM_CLASSES);
                                }
                                other => panic!("{key} image {i}: expected reply, got {other:?}"),
                            }
                        }
                    }
                });
            }
        });
        let report = server.shutdown(Duration::from_secs(10));
        assert_eq!(report.drain.dropped, 0, "{workers} workers: drain dropped requests");
        assert_eq!(report.drain.report.requests as usize, 4 * 12 * fleet.len());
    }
}

#[test]
fn connection_churn_neither_drops_nor_duplicates() {
    // a NEW connection per request: accept/close churn must not lose or
    // duplicate anything
    let _g = obs_lock();
    let engine = Engine::start(load_lw(), &ServeConfig::default());
    let server = NetServer::start(engine, &NetConfig::default()).unwrap();
    let addr = server.local_addr();
    let ids: Mutex<Vec<u64>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for c in 0..8u64 {
            let ids = &ids;
            s.spawn(move || {
                let ds = Dataset::new(c);
                for i in 0..16u64 {
                    let id = c * 16 + i;
                    let (img, _) = ds.sample(Split::Val, i);
                    let mut stream = TcpStream::connect(addr).unwrap();
                    let req = Frame::Infer {
                        id,
                        slot_key: "synthetic/lw".to_string(),
                        image: img,
                    };
                    frame::write_frame(&mut stream, &req).unwrap();
                    match frame::read_frame(&mut stream).unwrap() {
                        Frame::Reply { id: rid, .. } => ids.lock().unwrap().push(rid),
                        other => panic!("request {id}: expected reply, got {other:?}"),
                    }
                }
            });
        }
    });
    let mut got = ids.into_inner().unwrap();
    got.sort_unstable();
    got.dedup();
    assert_eq!(got.len(), 128, "every request answered exactly once");
    let report = server.shutdown(Duration::from_secs(10));
    assert_eq!(report.drain.report.requests, 128);
    assert_eq!(report.drain.dropped, 0);
}

#[test]
fn wire_malformed_frames_get_typed_replies_and_server_survives() {
    let _g = obs_lock();
    let fleet = load_lw();
    let image_len = fleet.slot(0).unwrap().image_len();
    let engine = Engine::start(fleet, &ServeConfig::default());
    let server = NetServer::start(engine, &NetConfig::default()).unwrap();
    let addr = server.local_addr();
    let ds = Dataset::new(3);
    let valid = |id: u64| Frame::Infer {
        id,
        slot_key: "synthetic/lw".to_string(),
        image: ds.sample(Split::Val, id).0,
    };

    // a poisoned byte stream (bad header) gets one typed reply, then close
    let mut stream = TcpStream::connect(addr).unwrap();
    std::io::Write::write_all(&mut stream, &raw(TY_INFER, 9, 0, &[])).unwrap();
    match frame::read_frame(&mut stream).unwrap() {
        Frame::Error { code, .. } => assert_eq!(code, ErrCode::BadVersion),
        other => panic!("expected bad-version error, got {other:?}"),
    }
    let mut probe = [0u8; 1];
    assert_eq!(std::io::Read::read(&mut stream, &mut probe).unwrap(), 0, "server must close");

    // payload-level failures keep the connection alive: each error frame is
    // followed by a successful request on the SAME connection
    let mut stream = TcpStream::connect(addr).unwrap();
    let cases = [
        (
            Frame::Infer { id: 1, slot_key: "nope/nah".into(), image: vec![0.0; 8] },
            ErrCode::UnknownSlot,
        ),
        (
            Frame::Infer { id: 2, slot_key: "synthetic/lw".into(), image: vec![0.0; 3] },
            ErrCode::PayloadSize,
        ),
        (
            Frame::Reply { id: 3, top1: 0, batch: 1, latency_us: 0, logits: vec![] },
            ErrCode::Malformed,
        ),
    ];
    for (bad, want_code) in cases {
        let id = bad.id();
        frame::write_frame(&mut stream, &bad).unwrap();
        match frame::read_frame(&mut stream).unwrap() {
            Frame::Error { id: rid, code, msg } => {
                assert_eq!(rid, id, "error echoes the request id");
                assert_eq!(code, want_code, "{msg}");
                assert!(!msg.is_empty(), "error frames carry a human-readable cause");
            }
            other => panic!("request {id}: expected {want_code:?} error, got {other:?}"),
        }
        frame::write_frame(&mut stream, &valid(id + 100)).unwrap();
        match frame::read_frame(&mut stream).unwrap() {
            Frame::Reply { id: rid, logits, .. } => {
                assert_eq!(rid, id + 100);
                assert_eq!(logits.len(), NUM_CLASSES);
            }
            other => panic!("connection did not survive {want_code:?}: {other:?}"),
        }
    }
    drop(stream);
    // sanity: the whole gauntlet never wedged a worker
    let report = server.shutdown(Duration::from_secs(10));
    assert_eq!(report.drain.dropped, 0);
    assert_eq!(report.drain.report.requests, 3, "{image_len}-float slot served 3 valid requests");
}

// -------------------------------------------- overload + graceful drain

/// A delegating [`PreparedNet`] that sleeps before forwarding — makes the
/// worker the bottleneck so admission control and drain deadlines are
/// actually exercised.
struct SlowNet {
    inner: Box<dyn PreparedNet>,
    delay: Duration,
}

impl PreparedNet for SlowNet {
    fn kind(&self) -> BackendKind {
        self.inner.kind()
    }
    fn input_hw(&self) -> usize {
        self.inner.input_hw()
    }
    fn input_ch(&self) -> usize {
        self.inner.input_ch()
    }
    fn num_classes(&self) -> usize {
        self.inner.num_classes()
    }
    fn forward_batch(&self, x: &Tensor, scratch: &mut Scratch, pool: &Pool) -> Tensor {
        std::thread::sleep(self.delay);
        self.inner.forward_batch(x, scratch, pool)
    }
    fn forward_batch_feat(
        &self,
        x: &Tensor,
        scratch: &mut Scratch,
        pool: &Pool,
    ) -> (Tensor, Tensor) {
        std::thread::sleep(self.delay);
        self.inner.forward_batch_feat(x, scratch, pool)
    }
}

/// Install a slowed twin of the slot's v1 and route all traffic to it.
fn promote_slow(fleet: &Fleet, delay: Duration) {
    let slot = fleet.slot(0).unwrap();
    let v1 = slot.primary();
    let inner = backend::prepare(v1.kind, &slot.arch, &v1.params);
    let v = slot
        .install(v1.kind, Box::new(SlowNet { inner, delay }), v1.params.clone(), "slow twin".into())
        .unwrap();
    slot.promote(v).unwrap();
}

#[test]
fn overload_sheds_busy_and_queue_stays_bounded() {
    let _g = obs_lock();
    qft::obs::reset();
    let fleet = load_lw();
    promote_slow(&fleet, Duration::from_millis(40));
    const CAP: usize = 2;
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_micros(50),
        queue_cap: CAP,
        adaptive: false,
    };
    let engine = Engine::start(fleet.clone(), &cfg);
    let server = NetServer::start(engine, &NetConfig::default()).unwrap();
    let addr = server.local_addr();

    let clients = 10usize;
    let gate = Barrier::new(clients);
    let stop = AtomicBool::new(false);
    let replies = AtomicUsize::new(0);
    let busy = AtomicUsize::new(0);
    let max_depth = std::thread::scope(|s| {
        // sample the global queue-depth gauge while the burst runs: the
        // bounded queue must never exceed its cap
        let sampler = s.spawn(|| {
            let mut max_seen = 0i64;
            while !stop.load(Ordering::Relaxed) {
                max_seen = max_seen.max(qft::obs::queue_depth().get());
                std::thread::sleep(Duration::from_micros(300));
            }
            max_seen
        });
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let (gate, replies, busy) = (&gate, &replies, &busy);
                s.spawn(move || {
                    let mut stream = TcpStream::connect(addr).unwrap();
                    let ds = Dataset::new(c as u64);
                    gate.wait();
                    for i in 0..3u64 {
                        let (img, _) = ds.sample(Split::Val, i);
                        let req = Frame::Infer {
                            id: i,
                            slot_key: "synthetic/lw".to_string(),
                            image: img,
                        };
                        frame::write_frame(&mut stream, &req).unwrap();
                        match frame::read_frame(&mut stream).unwrap() {
                            Frame::Reply { .. } => {
                                replies.fetch_add(1, Ordering::Relaxed);
                            }
                            Frame::Error { code: ErrCode::Busy, .. } => {
                                busy.fetch_add(1, Ordering::Relaxed);
                            }
                            other => panic!("client {c} request {i}: {other:?}"),
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        sampler.join().unwrap()
    });

    let (replies, busy) = (replies.into_inner(), busy.into_inner());
    assert_eq!(replies + busy, clients * 3, "every request got exactly one typed answer");
    assert!(replies > 0, "something must still be served under overload");
    assert!(busy > 0, "a 10-way burst into a 2-deep queue must shed");
    assert!(
        max_depth as usize <= CAP,
        "queue depth {max_depth} exceeded its cap {CAP} — admission control leaked"
    );
    let report = server.shutdown(Duration::from_secs(10));
    assert_eq!(report.drain.report.requests as usize, replies);
}

#[test]
fn engine_drain_reports_dropped_requests_on_deadline() {
    let _g = obs_lock();
    let fleet = load_lw();
    promote_slow(&fleet, Duration::from_millis(100));
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_micros(50),
        queue_cap: 64,
        adaptive: false,
    };

    // deadline far shorter than the queued work: the drain must purge,
    // answer every purged request with a typed Shutdown, and say so
    let engine = Engine::start(fleet.clone(), &cfg);
    let client = engine.client();
    let ds = Dataset::new(5);
    let rxs: Vec<_> = (0..6u64)
        .map(|i| client.try_submit(0, ds.sample(Split::Val, i).0).unwrap())
        .collect();
    std::thread::sleep(Duration::from_millis(20)); // let the worker take one
    let drain = engine.drain(Duration::from_millis(1));
    assert!(drain.timed_out, "a 1 ms deadline against 100 ms batches must time out");
    assert!(drain.dropped >= 4, "most of the queue must be shed (dropped {})", drain.dropped);
    let (mut served, mut shut) = (0usize, 0usize);
    for rx in rxs {
        match rx.recv_timeout(Duration::from_secs(5)).expect("every request is answered") {
            Ok(_) => served += 1,
            Err(Reject::Shutdown) => shut += 1,
            Err(other) => panic!("unexpected rejection {other:?}"),
        }
    }
    assert_eq!(served + shut, 6, "no request may vanish in a drain");
    assert_eq!(shut, drain.dropped, "the report counts exactly the shed requests");
    assert!(served >= 1, "in-flight work finishes even past the deadline");

    // generous deadline: everything finishes, nothing is dropped
    let engine = Engine::start(fleet, &cfg);
    let client = engine.client();
    let rxs: Vec<_> = (0..2u64)
        .map(|i| client.try_submit(0, ds.sample(Split::Val, i).0).unwrap())
        .collect();
    let drain = engine.drain(Duration::from_secs(20));
    assert_eq!(drain.dropped, 0);
    assert!(!drain.timed_out, "an empty queue at the deadline is not a timeout");
    for rx in rxs {
        assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
    }
}
