"""AOT pipeline: lower every (arch x entry-point) to HLO *text* + manifest.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that the image's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Python runs exactly once (`make artifacts`); the rust leader then drives
everything through PJRT.  `manifest.json` is the contract: architecture IR,
flat input/output orderings per artifact, and scalar conventions (all
scalars are shape-(1,) f32 literals).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import archs, model, qft
from .archs import BATCH, INPUT_CH, INPUT_HW, Arch

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sds(shape):
    return jax.ShapeDtypeStruct(tuple(shape), F32)


def _spec_list(pairs):
    return [{"name": n, "shape": list(s)} for n, s in pairs]


def _images_spec():
    return ("images", (BATCH, INPUT_HW, INPUT_HW, INPUT_CH))


def build_entries(arch: Arch):
    """Every exported entry point for one arch: name -> (fn, in_specs, out_specs)."""
    p = arch.param_specs()
    pm = [(f"m.{n}", s) for n, s in p]
    pv = [(f"v.{n}", s) for n, s in p]
    entries = {}

    ins = p + pm + pv + [("t", (1,)), ("lr", (1,)), _images_spec(),
                         ("labels", (BATCH,))]
    outs = p + pm + pv + [("loss", ())]
    entries["fp_train"] = (model.make_fp_train(arch), ins, outs)

    ins = p + [_images_spec()]
    outs = [("logits", (BATCH, archs.NUM_CLASSES)),
            ("feat", (BATCH, arch.feat_channels()))]
    entries["fp_eval"] = (model.make_fp_eval(arch), ins, outs)

    ch = arch.value_channels()
    outs = [(f"absmax:{v}", (ch[v],)) for v in arch.quantized_values()]
    entries["fp_stats"] = (model.make_fp_stats(arch), ins, outs)

    for mode in ("lw", "dch"):
        tr = arch.trainable_specs(mode)
        tm = [(f"m.{n}", s) for n, s in tr]
        tv = [(f"v.{n}", s) for n, s in tr]
        ins = (tr + tm + tv +
               [("t", (1,)), ("lr", (1,)), ("ce_mix", (1,)),
                ("train_scales", (1,))] +
               [(f"teacher.{n}", s) for n, s in p] + [_images_spec()])
        outs = tr + tm + tv + [("loss", ())]
        entries[f"qft_train_{mode}"] = (qft.make_qft_train(arch, mode), ins, outs)

        ins = tr + [_images_spec()]
        outs = [("logits", (BATCH, archs.NUM_CLASSES)),
                ("feat", (BATCH, arch.feat_channels()))]
        entries[f"q_eval_{mode}"] = (qft.make_q_eval(arch, mode), ins, outs)

    return entries


def lower_arch(arch: Arch, outdir: str, manifest: dict, verbose: bool = True):
    arts = {}
    for ename, (fn, ins, outs) in build_entries(arch).items():
        fname = f"{arch.name}_{ename}.hlo.txt"
        path = os.path.join(outdir, fname)
        lowered = jax.jit(fn, keep_unused=True).lower(*[_sds(s) for _, s in ins])
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        arts[ename] = {"file": fname, "inputs": _spec_list(ins),
                       "outputs": _spec_list(outs)}
        if verbose:
            print(f"  {fname}: {len(ins)} in / {len(outs)} out, "
                  f"{len(text) // 1024} KiB")
    spec = arch.to_json()
    spec["artifacts"] = arts
    manifest["archs"][arch.name] = spec


def lower_kernel_microbench(outdir: str, manifest: dict):
    """Standalone L1 kernel artifacts for rust-side micro-benchmarks."""
    from .kernels.fakequant import fakequant
    from .kernels.qmatmul import qmatmul

    m, k, n = 256, 128, 128
    ins = [("x", (m, k)), ("w", (k, n)), ("s_l", (k,)), ("s_r", (n,))]

    def kq(x, w, s_l, s_r):
        return (qmatmul(x, w, s_l, s_r, -7.0, 7.0),)

    lowered = jax.jit(kq, keep_unused=True).lower(*[_sds(s) for _, s in ins])
    with open(os.path.join(outdir, "kernel_qmatmul.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    def kf(x, s_r):
        return (fakequant(x, s_r[None, :], -7.0, 7.0),)

    lowered = jax.jit(kf, keep_unused=True).lower(_sds((m, k)), _sds((k,)))
    with open(os.path.join(outdir, "kernel_fakequant.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    manifest["kernels"] = {
        "qmatmul": {"file": "kernel_qmatmul.hlo.txt", "inputs": _spec_list(ins),
                    "outputs": [{"name": "y", "shape": [m, n]}]},
        "fakequant": {"file": "kernel_fakequant.hlo.txt",
                      "inputs": _spec_list([("x", (m, k)), ("s_r", (k,))]),
                      "outputs": [{"name": "y", "shape": [m, k]}]},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts directory")
    ap.add_argument("--archs", default="all",
                    help="comma-separated arch names, or 'all'")
    args = ap.parse_args()

    outdir = os.path.dirname(args.out) if args.out.endswith(".hlo.txt") else args.out
    os.makedirs(outdir, exist_ok=True)

    names = list(archs.ZOO) if args.archs == "all" else args.archs.split(",")
    manifest = {"batch": BATCH, "input_hw": INPUT_HW, "input_ch": INPUT_CH,
                "num_classes": archs.NUM_CLASSES, "archs": {}}
    for name in names:
        print(f"lowering {name} ...")
        lower_arch(archs.get_arch(name), outdir, manifest)
    lower_kernel_microbench(outdir, manifest)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {os.path.join(outdir, 'manifest.json')}")


if __name__ == "__main__":
    main()
