"""L2 QFT twin-graph simulation (paper Fig. 4 / Fig. 11) and train step.

The student is a *deployment-aware* graph in two parts:

  offline subgraph — infers every deployment constant from the independent
    DoF set (Eq. 2 and its inversion Eqs. 3-4).  In `lw` mode (W4A8,
    layerwise/scalar HW rescale) the DoF are {W, b, S_a vectors, F scalars}
    and the kernel grid is the outer product
        S_w[m, n] = (1 / S_a^{l-1})_m * (S_a^l * F^l)_n ,
    which *is* the trainable cross-layer-factorization (CLE) DoF.  In `dch`
    mode (W4A32, channelwise rescale) the DoF are the explicit left/right
    kernel scale co-vectors {S_wL, S_wR} of the doubly-channelwise scheme.

  online subgraph — HW-runtime emulation: convs against the fake-quantized
    kernel (fused Pallas `qmatmul` for pointwise convs), bias add, activation,
    and 8b activation fake-quant (lw mode).  Elementwise-add and the gap/fc
    head are taken full-precision per the paper (App. D item 1, §4).

Everything is end-to-end differentiable through the STE decorating each
clip(round(.)) (see kernels/), so weights, biases, activation scales and
rescale factors train on the same footing — no per-parameter gradient rules.

Training loss: knowledge distillation from the FP teacher — normalized L2 on
the backbone output (pre-gap feature map), optionally mixed with CE on soft
logits (Fig. 6 ablation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import archs, model
from .archs import ACT_SIGNED_QMAX, ACT_UNSIGNED_QMAX, WEIGHT_QMAX, Arch
from .kernels.fakequant import fakequant
from .kernels.qmatmul import qmatmul

EPS = 1e-12
WQ = float(WEIGHT_QMAX)


def _tr_map(arch: Arch, mode: str, trainables):
    return {name: t for (name, _), t in zip(arch.trainable_specs(mode), trainables)}


def _pos(s):
    """Scale DoF are unconstrained variables; the offline subgraph maps them
    to strictly positive grids (|s| + eps) so training can move through 0."""
    return jnp.abs(s) + EPS


def _act_range(signed: bool):
    return (-ACT_SIGNED_QMAX, ACT_SIGNED_QMAX) if signed else (0.0, ACT_UNSIGNED_QMAX)


def kernel_scale_lw(tm, o, quant_in_vid):
    """Offline subgraph, lw mode: Eq. 2 for one conv."""
    su = _pos(tm[f"sv:{quant_in_vid}"])            # (cin,)  = S_a^{l-1}
    sv = _pos(tm[f"sv:{o.out}"])                   # (cout,) = S_a^l
    f = _pos(tm[f"f:{o.name}"])                    # (1,)    = F^l (scalar, lw)
    if o.groups == 1:
        s_l = 1.0 / su                             # left co-vector
        s_r = sv * f                               # right co-vector
        return s_l, s_r
    # depthwise: single channel axis, in-channel m == out-channel m
    return None, (sv * f) / su                     # (cout,)


def kernel_scale_dch(tm, o):
    """Offline subgraph, dch mode: explicit L/R co-vectors (Eqs. 3-4)."""
    if o.groups == 1:
        return _pos(tm[f"swl:{o.name}"]), _pos(tm[f"swr:{o.name}"])
    return None, _pos(tm[f"swr:{o.name}"])


def _qconv(x, w, b, o, s_l, s_r):
    """Online conv against the fake-quantized kernel."""
    if o.k == 1 and o.groups == 1 and o.stride == 1:
        # pointwise conv == matmul: use the fused Pallas kernel
        bsz, h, wd, cin = x.shape
        y = qmatmul(x.reshape(-1, cin), w.reshape(cin, o.cout), s_l, s_r,
                    -WQ, WQ)
        y = y.reshape(bsz, h, wd, o.cout)
    else:
        if s_l is None:  # depthwise
            s_w = s_r[None, None, None, :]
        else:
            s_w = s_l[None, None, :, None] * s_r[None, None, None, :]
        wq = fakequant(w, s_w, -WQ, WQ)
        y = jax.lax.conv_general_dilated(
            x, wq, window_strides=(o.stride, o.stride), padding="SAME",
            feature_group_count=o.groups,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def student_forward(arch: Arch, mode: str, trainables, x):
    """Quantized-student forward. Returns (logits, feat_map)."""
    tm = _tr_map(arch, mode, trainables)
    signed = arch.value_signed()
    vals = {}
    if mode == "lw":
        qmin, qmax = _act_range(signed[0])
        vals[0] = fakequant(x, _pos(tm["sv:0"])[None, None, None, :], qmin, qmax)
    else:
        vals[0] = x
    feat = None
    logits = None
    for o in arch.ops:
        if o.op == "conv":
            w, b = tm[f"w:{o.name}"], tm[f"b:{o.name}"]
            if mode == "lw":
                s_l, s_r = kernel_scale_lw(tm, o, o.inp)
            else:
                s_l, s_r = kernel_scale_dch(tm, o)
            a = model._act(_qconv(vals[o.inp], w, b, o, s_l, s_r), o.act)
            if mode == "lw":
                qmin, qmax = _act_range(signed[o.out])
                sv = _pos(tm[f"sv:{o.out}"])
                a = fakequant(a, sv[None, None, None, :], qmin, qmax)
            vals[o.out] = a
        elif o.op == "add":
            a = model._act(vals[o.a] + vals[o.b], o.act)
            if mode == "lw":
                qmin, qmax = _act_range(signed[o.out])
                sv = _pos(tm[f"sv:{o.out}"])
                a = fakequant(a, sv[None, None, None, :], qmin, qmax)
            vals[o.out] = a
        elif o.op == "gap":
            feat = vals[o.inp]
            vals[o.out] = jnp.mean(vals[o.inp], axis=(1, 2))
        elif o.op == "fc":
            logits = vals[o.inp] @ tm[f"w:{o.name}"] + tm[f"b:{o.name}"]
            vals[o.out] = logits
    return logits, feat


def kd_loss(arch: Arch, mode: str, trainables, teacher_params, images, ce_mix):
    """(1-p) * normalized-L2(backbone feat) + p * CE(soft logits)."""
    t_logits, t_feat, _ = model.forward(arch, teacher_params, images)
    t_logits = jax.lax.stop_gradient(t_logits)
    t_feat = jax.lax.stop_gradient(t_feat)
    s_logits, s_feat = student_forward(arch, mode, trainables, images)

    diff = (t_feat - s_feat).reshape(t_feat.shape[0], -1)
    tf = t_feat.reshape(t_feat.shape[0], -1)
    l2 = jnp.mean(jnp.sum(diff * diff, axis=-1) /
                  (jnp.sum(tf * tf, axis=-1) + 1e-6))

    p_t = jax.nn.softmax(t_logits)
    ce = -jnp.mean(jnp.sum(p_t * jax.nn.log_softmax(s_logits), axis=-1))
    return (1.0 - ce_mix) * l2 + ce_mix * ce


def _scale_mask(arch: Arch, mode: str):
    """1.0 for scale-type DoF (sv/f/swl/swr), 0.0 for weights/biases."""
    return [1.0 if n.split(":")[0] in ("sv", "f", "swl", "swr") else 0.0
            for n, _ in arch.trainable_specs(mode)]


# --------------------------------------------------------------------------
# Exported entry points
# --------------------------------------------------------------------------

def make_qft_train(arch: Arch, mode: str):
    """(trainables.., m.., v.., t, lr, ce_mix, train_scales,
        teacher_params.., images) -> (trainables'.., m'.., v'.., loss)

    `train_scales` in {0,1} gates gradient flow into the scale DoF — the
    frozen-scales arm of the Fig. 8 / Fig. 9 ablations — without needing a
    separate compiled graph.  Scalars arrive as shape-(1,) f32 literals.
    """
    n = len(arch.trainable_specs(mode))
    np_ = len(arch.param_specs())
    mask = _scale_mask(arch, mode)

    def step(*args):
        tr = list(args[:n])
        m = list(args[n:2 * n])
        v = list(args[2 * n:3 * n])
        t, lr, ce_mix, train_scales = args[3 * n:3 * n + 4]
        teacher = list(args[3 * n + 4:3 * n + 4 + np_])
        images = args[3 * n + 4 + np_]
        t, lr = t[0], lr[0]
        ce_mix, train_scales = ce_mix[0], train_scales[0]

        loss, grads = jax.value_and_grad(
            lambda tr_: kd_loss(arch, mode, tr_, teacher, images, ce_mix))(tr)
        grads = [g * (1.0 - mk + mk * train_scales)
                 for g, mk in zip(grads, mask)]
        new_t, new_m, new_v = model.adam_update(tr, grads, m, v, t, lr)
        return tuple(new_t + new_m + new_v + [loss])

    return step


def make_q_eval(arch: Arch, mode: str):
    """(trainables.., images) -> (logits, feat_gap)"""
    n = len(arch.trainable_specs(mode))

    def run(*args):
        tr = list(args[:n])
        images = args[n]
        logits, feat = student_forward(arch, mode, tr, images)
        return (logits, jnp.mean(feat, axis=(1, 2)))

    return run
