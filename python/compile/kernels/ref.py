"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package must agree with its oracle here to float32
round-off; `python/tests/test_kernels.py` sweeps shapes/scales with
hypothesis and asserts allclose.
"""

import jax.numpy as jnp


def fakequant_ref(x, s, qmin: float, qmax: float):
    """s * clip(round(x / s), qmin, qmax); s broadcastable to x.shape."""
    q = x / s
    return jnp.clip(jnp.round(q), qmin, qmax) * s


def fakequant_grads_ref(g, x, s, qmin: float, qmax: float):
    """Analytic STE/LSQ-style cotangents for fakequant.

    Treating round() as identity in backward (STE), autodiff of
    s * clip(round(x/s)) gives
        dL/dx = g            inside the clip range, 0 outside
        dL/ds = g * (r - q)  inside,  g * r  outside      (r = clipped round)
    ds is reduced back to s's (broadcastable) shape.
    """
    sb = jnp.broadcast_to(s, x.shape)
    q = x / sb
    r = jnp.clip(jnp.round(q), qmin, qmax)
    inside = ((q >= qmin) & (q <= qmax)).astype(x.dtype)
    dx = g * inside
    ds_full = g * (r - q * inside)
    ds = _unbroadcast(ds_full, jnp.shape(s))
    return dx, ds


def _unbroadcast(t, shape):
    """Sum-reduce t back to `shape` (inverse of broadcast_to)."""
    extra = t.ndim - len(shape)
    if extra > 0:
        t = t.sum(axis=tuple(range(extra)))
    axes = tuple(i for i, d in enumerate(shape) if d == 1 and t.shape[i] != 1)
    if axes:
        t = t.sum(axis=axes, keepdims=True)
    return t.reshape(shape)


def qmatmul_ref(x, w, s, qmin: float, qmax: float):
    """x @ fakequant(w, s): the fused quantized-matmul oracle."""
    return x @ fakequant_ref(w, s, qmin, qmax)
