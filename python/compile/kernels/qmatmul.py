"""L1 fused quantized matmul: ``x @ fakequant(w, outer(s_l, s_r))``.

This is the online-subgraph hot spot of the student network: pointwise (1x1)
convolutions and im2col'd convs reduce to a matmul against a 4b fake-quantized
weight matrix whose grid is the outer product of the left/right scale
co-vectors (Eq. 2 / Eq. 10).

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernel tiles (M, K) x (K, N)
into MXU-shaped VMEM blocks; the weight tile is fake-quantized *in VMEM* right
before the dot, so the requantized kernel never round-trips to HBM — the
Pallas analogue of fusing the quantize into the threadblock the paper's GPU
stack relies on XLA for.  interpret=True in this image (CPU PJRT).

Backward is delegated to jax.vjp over the jnp oracle composition, which routes
STE cotangents into x, w, s_l, s_r natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# MXU-shaped tiles; K is kept whole per block (K <= a few hundred here).
_BM = 128
_BN = 128


def _qmm_kernel(x_ref, w_ref, sl_ref, sr_ref, o_ref, *, qmin, qmax):
    x = x_ref[...]
    w = w_ref[...]
    s = sl_ref[...][:, None] * sr_ref[...][None, :]
    wq = jnp.clip(jnp.round(w / s), qmin, qmax) * s
    o_ref[...] = jnp.dot(x, wq, preferred_element_type=jnp.float32)


def _qmm_pallas(x, w, s_l, s_r, qmin, qmax):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and s_l.shape == (k,) and s_r.shape == (n,)
    kern = functools.partial(_qmm_kernel, qmin=qmin, qmax=qmax)
    if m % _BM == 0 and n % _BN == 0 and (m > _BM or n > _BN):
        grid = (m // _BM, n // _BN)
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[
                pl.BlockSpec((_BM, k), lambda i, j: (i, 0)),
                pl.BlockSpec((k, _BN), lambda i, j: (0, j)),
                pl.BlockSpec((k,), lambda i, j: (0,)),
                pl.BlockSpec((_BN,), lambda i, j: (j,)),
            ],
            out_specs=pl.BlockSpec((_BM, _BN), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
            interpret=True,
        )(x, w, s_l, s_r)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, s_l, s_r)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def qmatmul(x, w, s_l, s_r, qmin: float, qmax: float):
    """x[m,k] @ fakequant(w[k,n], s_l[k] ⊗ s_r[n]) with STE gradients."""
    return _qmm_pallas(x, w, s_l, s_r, qmin, qmax)


def _qmm_fwd(x, w, s_l, s_r, qmin, qmax):
    return qmatmul(x, w, s_l, s_r, qmin, qmax), (x, w, s_l, s_r)


def _qmm_bwd(qmin, qmax, res, g):
    x, w, s_l, s_r = res

    def composed(x, w, s_l, s_r):
        s = s_l[:, None] * s_r[None, :]
        q = w / s
        inside = ((q >= qmin) & (q <= qmax)).astype(w.dtype)
        # Differentiable surrogate with exactly the STE cotangents:
        # wq = s * (r + inside * (q - stop_grad(q))), r = stop_grad(clip round)
        # value == fakequant_ref; d/dw == inside; d/ds == r - inside * q.
        r = jax.lax.stop_gradient(jnp.clip(jnp.round(q), qmin, qmax))
        wq = s * (r + inside * (q - jax.lax.stop_gradient(q)))
        return x @ wq

    _, vjp = jax.vjp(composed, x, w, s_l, s_r)
    return vjp(g)


qmatmul.defvjp(_qmm_fwd, _qmm_bwd)
