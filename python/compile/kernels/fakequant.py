"""L1 Pallas fake-quantization kernel with STE gradients.

The lossy element of Fig. 4/11: ``s * clip(round(x/s), qmin, qmax)``.  The
forward pass is a Pallas kernel (interpret=True in this image — real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute); the
backward pass is the analytic STE/LSQ cotangent, so gradients flow *natively*
into whatever computes ``s`` — the offline subgraph (outer products of L/R
co-vectors, Eq. 2) — with no per-parameter gradient definitions.

TPU notes (DESIGN.md §Hardware-Adaptation): fake-quant is pure VPU work.  We
block the tensor into VMEM-resident tiles; for the small shapes of this repo a
single block suffices, for larger tensors a (256, 128) grid keeps the tile
footprint at 128 KiB (3 buffers) with room for double-buffering in 16 MiB VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Tile shape for 2-D blocked dispatch (VPU lane-friendly multiple of (8,128)).
_BLOCK = (256, 128)


def _fq_kernel(x_ref, s_ref, o_ref, *, qmin, qmax):
    x = x_ref[...]
    s = s_ref[...]
    q = x / s
    o_ref[...] = jnp.clip(jnp.round(q), qmin, qmax) * s


def _fq_pallas(x, sb, qmin, qmax):
    """Forward Pallas dispatch: single block for small tensors, 2-D grid of
    VMEM tiles for large 2-D tensors."""
    kern = functools.partial(_fq_kernel, qmin=qmin, qmax=qmax)
    if x.ndim == 2 and x.shape[0] % _BLOCK[0] == 0 and x.shape[1] % _BLOCK[1] == 0 \
            and x.size > _BLOCK[0] * _BLOCK[1]:
        grid = (x.shape[0] // _BLOCK[0], x.shape[1] // _BLOCK[1])
        spec = pl.BlockSpec(_BLOCK, lambda i, j: (i, j))
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[spec, spec],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True,
        )(x, sb)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, sb)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def fakequant(x, s, qmin: float, qmax: float):
    """Fake-quantize x on the grid s with saturation [qmin, qmax].

    ``s`` may be any shape broadcastable to ``x.shape`` (scalar, per-channel
    vector, or a full doubly-channelwise outer product).  Differentiable in
    both x and s via STE.
    """
    sb = jnp.broadcast_to(s, x.shape).astype(x.dtype)
    return _fq_pallas(x, sb, qmin, qmax)


def _fq_fwd(x, s, qmin, qmax):
    return fakequant(x, s, qmin, qmax), (x, s)


def _fq_bwd(qmin, qmax, res, g):
    x, s = res
    return ref.fakequant_grads_ref(g, x, s, qmin, qmax)


fakequant.defvjp(_fq_fwd, _fq_bwd)
