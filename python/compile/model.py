"""L2 full-precision model: forward, CE pretrain step, calibration stats.

The FP network plays two roles in QFT (paper §3.1): it is the *teacher* for
knowledge distillation, and its pretrained weights are the student's init.
Since this repo substitutes ImageNet-pretrained models with tiny nets trained
in-repo (DESIGN.md), we also export an Adam cross-entropy `fp_train` step so
the rust leader can pretrain the teacher through PJRT — python stays off the
run path.

All functions take/return *flat lists* of arrays in `arch.param_specs()`
order; `aot.py` records that order in the manifest for the rust side.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import archs
from .archs import Arch

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def _act(x, kind: str):
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    return x


def _conv(x, w, b, stride: int, groups: int):
    y = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        feature_group_count=groups,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _param_map(arch: Arch, params):
    return {name: p for (name, _), p in zip(arch.param_specs(), params)}


def forward(arch: Arch, params, x, *, collect=False):
    """FP forward. Returns (logits, feat, values) where feat is the backbone
    output (KD tap, pre-gap) and values maps value-id -> tensor when
    ``collect`` (used for calibration statistics)."""
    pm = _param_map(arch, params)
    vals = {0: x}
    feat = None
    logits = None
    for o in arch.ops:
        if o.op == "conv":
            y = _conv(vals[o.inp], pm[f"w:{o.name}"], pm[f"b:{o.name}"],
                      o.stride, o.groups)
            vals[o.out] = _act(y, o.act)
        elif o.op == "add":
            vals[o.out] = _act(vals[o.a] + vals[o.b], o.act)
        elif o.op == "gap":
            feat = vals[o.inp]
            vals[o.out] = jnp.mean(vals[o.inp], axis=(1, 2))
        elif o.op == "fc":
            logits = vals[o.inp] @ pm[f"w:{o.name}"] + pm[f"b:{o.name}"]
            vals[o.out] = logits
    return logits, feat, (vals if collect else None)


def ce_loss(arch: Arch, params, images, labels):
    logits, _, _ = forward(arch, params, images)
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, archs.NUM_CLASSES, dtype=jnp.float32)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def adam_update(params, grads, m, v, t, lr):
    """One functional Adam step over flat lists; t is the 1-based step as f32."""
    new_p, new_m, new_v = [], [], []
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        mhat = mi / bc1
        vhat = vi / bc2
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


# --------------------------------------------------------------------------
# Exported entry points (flat signatures for AOT)
# --------------------------------------------------------------------------

def make_fp_train(arch: Arch):
    """(params.., m.., v.., t, lr, images, labels) ->
       (params'.., m'.., v'.., loss)"""
    n = len(arch.param_specs())

    def step(*args):
        params = list(args[:n])
        m = list(args[n:2 * n])
        v = list(args[2 * n:3 * n])
        t, lr, images, labels = args[3 * n:]
        t, lr = t[0], lr[0]  # scalars arrive as shape-(1,) f32 literals
        labels = labels.astype(jnp.int32)
        loss, grads = jax.value_and_grad(
            lambda p: ce_loss(arch, p, images, labels))(params)
        new_p, new_m, new_v = adam_update(params, grads, m, v, t, lr)
        return tuple(new_p + new_m + new_v + [loss])

    return step


def make_fp_eval(arch: Arch):
    """(params.., images) -> (logits, feat_gap)"""
    n = len(arch.param_specs())

    def run(*args):
        params = list(args[:n])
        images = args[n]
        logits, feat, _ = forward(arch, params, images)
        return (logits, jnp.mean(feat, axis=(1, 2)))

    return run


def make_fp_stats(arch: Arch):
    """(params.., images) -> per-quantized-value, per-channel max|.| vectors.

    The 'naive (max-min) range calibration' of §4: the rust coordinator
    reduces these per-batch maxima over the calibration set to initialize the
    activation scale DoF."""
    n = len(arch.param_specs())
    qvals = arch.quantized_values()

    def run(*args):
        params = list(args[:n])
        images = args[n]
        _, _, vals = forward(arch, params, images, collect=True)
        outs = []
        for vid in qvals:
            t = vals[vid]
            red = tuple(range(t.ndim - 1))
            outs.append(jnp.max(jnp.abs(t), axis=red))
        return tuple(outs)

    return run
