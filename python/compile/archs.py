"""Tiny-CNN architecture zoo shared by the L2 model and the L3 coordinator.

Each architecture is a flat op-list IR over *values* (tensor edges).  Value 0
is the network input; every op produces a new value id.  This IR is the
single source of truth: `aot.py` serializes it into `artifacts/manifest.json`
and the rust coordinator rebuilds the same deployment graph from it.

The zoo is the paper's ImageNet-model substitution (see DESIGN.md): six tiny
nets from three families (plain/residual conv, depthwise+relu6 mobilenet-like,
regnet-like widths), pretrained from scratch on a synthetic task by the rust
leader via the AOT `fp_train` step.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

INPUT_HW = 16
INPUT_CH = 3
NUM_CLASSES = 10
BATCH = 8

# Quantization grids (paper: 4b symmetric weights, 8b activations).
WEIGHT_QMAX = 7  # +/- (2^(4-1) - 1)
ACT_UNSIGNED_QMAX = 255.0
ACT_SIGNED_QMAX = 127.0


@dataclass
class Op:
    op: str  # conv | add | gap | fc
    name: str
    out: int  # output value id
    # conv fields
    inp: int = -1
    k: int = 0
    stride: int = 1
    cin: int = 0
    cout: int = 0
    groups: int = 1
    act: str = "none"  # none | relu | relu6
    # add fields
    a: int = -1
    b: int = -1

    def to_json(self) -> dict[str, Any]:
        d = {"op": self.op, "name": self.name, "out": self.out}
        if self.op == "conv":
            d.update(
                inp=self.inp, k=self.k, stride=self.stride, cin=self.cin,
                cout=self.cout, groups=self.groups, act=self.act,
            )
        elif self.op == "add":
            d.update(a=self.a, b=self.b, act=self.act)
        else:
            d.update(inp=self.inp, cin=self.cin, cout=self.cout)
        return d


@dataclass
class Arch:
    name: str
    ops: list[Op] = field(default_factory=list)
    nvals: int = 1  # value 0 = input

    # ------------------------------------------------------------------ build
    def _new_val(self) -> int:
        v = self.nvals
        self.nvals += 1
        return v

    def conv(self, inp: int, cin: int, cout: int, k: int = 3, stride: int = 1,
             groups: int = 1, act: str = "relu") -> int:
        out = self._new_val()
        self.ops.append(Op("conv", f"conv{len(self.ops)}", out, inp=inp, k=k,
                           stride=stride, cin=cin, cout=cout, groups=groups,
                           act=act))
        return out

    def add(self, a: int, b: int, act: str = "none") -> int:
        out = self._new_val()
        self.ops.append(Op("add", f"add{len(self.ops)}", out, a=a, b=b, act=act))
        return out

    def gap(self, inp: int) -> int:
        out = self._new_val()
        self.ops.append(Op("gap", f"gap{len(self.ops)}", out, inp=inp))
        return out

    def fc(self, inp: int, cin: int, cout: int) -> int:
        out = self._new_val()
        self.ops.append(Op("fc", f"fc{len(self.ops)}", out, inp=inp,
                           cin=cin, cout=cout))
        return out

    # --------------------------------------------------------------- queries
    def conv_ops(self) -> list[Op]:
        return [o for o in self.ops if o.op == "conv"]

    def value_channels(self) -> dict[int, int]:
        ch = {0: INPUT_CH}
        for o in self.ops:
            if o.op == "conv":
                ch[o.out] = o.cout
            elif o.op == "add":
                ch[o.out] = ch[o.a]
            elif o.op == "gap":
                ch[o.out] = ch[o.inp]
            elif o.op == "fc":
                ch[o.out] = o.cout
        return ch

    def value_signed(self) -> dict[int, bool]:
        """Unsigned (post-relu / input image) vs signed 8b encoding per value."""
        signed = {0: False}  # images in [0, 1]
        for o in self.ops:
            if o.op in ("conv", "add"):
                signed[o.out] = o.act == "none"
            elif o.op == "gap":
                signed[o.out] = signed[o.inp]
            elif o.op == "fc":
                signed[o.out] = True
        return signed

    def quantized_values(self) -> list[int]:
        """Values that carry an 8b encoding (trainable vector scale) in the
        deployment-oriented (lw, W4A8) mode: the input plus every conv/add
        output.  gap/fc stay full-precision (head excluded, see DESIGN.md)."""
        vals = [0]
        for o in self.ops:
            if o.op in ("conv", "add"):
                vals.append(o.out)
        return vals

    def backbone_value(self) -> int:
        """KD tap: input to the global average pooling (spatially rich)."""
        for o in self.ops:
            if o.op == "gap":
                return o.inp
        raise ValueError("arch has no gap")

    def feat_channels(self) -> int:
        return self.value_channels()[self.backbone_value()]

    # ------------------------------------------------------------ param spec
    def param_specs(self) -> list[tuple[str, tuple[int, ...]]]:
        """FP parameter list, in manifest order. Conv weights HWIO."""
        specs: list[tuple[str, tuple[int, ...]]] = []
        for o in self.ops:
            if o.op == "conv":
                specs.append((f"w:{o.name}", (o.k, o.k, o.cin // o.groups, o.cout)))
                specs.append((f"b:{o.name}", (o.cout,)))
            elif o.op == "fc":
                specs.append((f"w:{o.name}", (o.cin, o.cout)))
                specs.append((f"b:{o.name}", (o.cout,)))
        return specs

    def trainable_specs(self, mode: str) -> list[tuple[str, tuple[int, ...]]]:
        """QFT trainables (Eq. 6 / Eqs. 3-4), in manifest order.

        lw  (W4A8, scalar rescale):  weights, biases, per-value activation
            vector scales S_a (the CLE DoF), per-conv scalar rescale F.
        dch (W4A32, channelwise HW): weights, biases, per-conv left/right
            kernel scale co-vectors S_wL (cin), S_wR (cout).
        """
        ch = self.value_channels()
        specs: list[tuple[str, tuple[int, ...]]] = []
        for o in self.ops:
            if o.op == "conv":
                specs.append((f"w:{o.name}", (o.k, o.k, o.cin // o.groups, o.cout)))
                specs.append((f"b:{o.name}", (o.cout,)))
            elif o.op == "fc":
                # FP head rides along (gradient only flows when ce_mix > 0)
                specs.append((f"w:{o.name}", (o.cin, o.cout)))
                specs.append((f"b:{o.name}", (o.cout,)))
        if mode == "lw":
            for v in self.quantized_values():
                specs.append((f"sv:{v}", (ch[v],)))
            for o in self.conv_ops():
                specs.append((f"f:{o.name}", (1,)))
        elif mode == "dch":
            for o in self.conv_ops():
                if o.groups == 1:
                    specs.append((f"swl:{o.name}", (o.cin,)))
                specs.append((f"swr:{o.name}", (o.cout,)))
        else:
            raise ValueError(f"unknown mode {mode}")
        return specs

    def to_json(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "input_hw": INPUT_HW,
            "input_ch": INPUT_CH,
            "num_classes": NUM_CLASSES,
            "batch": BATCH,
            "nvals": self.nvals,
            "backbone_value": self.backbone_value(),
            "feat_channels": self.feat_channels(),
            "ops": [o.to_json() for o in self.ops],
            "params": [{"name": n, "shape": list(s)} for n, s in self.param_specs()],
            "trainables": {
                m: [{"name": n, "shape": list(s)}
                    for n, s in self.trainable_specs(m)]
                for m in ("lw", "dch")
            },
            "quantized_values": self.quantized_values(),
            "value_channels": {str(k): v for k, v in self.value_channels().items()},
            "value_signed": {str(k): v for k, v in self.value_signed().items()},
        }


# ---------------------------------------------------------------------------
# Zoo builders
# ---------------------------------------------------------------------------

def _basic_block(a: Arch, v: int, cin: int, cout: int, stride: int) -> int:
    """ResNet basic block: conv-relu, conv, (proj), add, relu."""
    h = a.conv(v, cin, cout, 3, stride, act="relu")
    h = a.conv(h, cout, cout, 3, 1, act="none")
    skip = v
    if stride != 1 or cin != cout:
        skip = a.conv(v, cin, cout, 1, stride, act="none")
    return a.add(h, skip, act="relu")


def _inverted_residual(a: Arch, v: int, cin: int, cout: int, stride: int,
                       expand: int, act: str = "relu6") -> int:
    """MobileNetV2 block: pw expand (act), dw (act), pw project (linear), add."""
    mid = cin * expand
    h = a.conv(v, cin, mid, 1, 1, act=act)
    h = a.conv(h, mid, mid, 3, stride, groups=mid, act=act)
    h = a.conv(h, mid, cout, 1, 1, act="none")
    if stride == 1 and cin == cout:
        h = a.add(h, v, act="none")
    return h


def convnet_tiny() -> Arch:
    a = Arch("convnet_tiny")
    v = a.conv(0, 3, 16, 3, 1)
    v = a.conv(v, 16, 16, 3, 2)
    v = a.conv(v, 16, 32, 3, 1)
    v = a.conv(v, 32, 32, 3, 2)
    v = a.gap(v)
    a.fc(v, 32, NUM_CLASSES)
    return a


def resnet_tiny() -> Arch:
    a = Arch("resnet_tiny")
    v = a.conv(0, 3, 16, 3, 1)
    v = _basic_block(a, v, 16, 16, 1)
    v = _basic_block(a, v, 16, 32, 2)
    v = _basic_block(a, v, 32, 32, 1)
    v = a.gap(v)
    a.fc(v, 32, NUM_CLASSES)
    return a


def resnet_wide() -> Arch:
    a = Arch("resnet_wide")
    v = a.conv(0, 3, 24, 3, 1)
    v = _basic_block(a, v, 24, 24, 1)
    v = _basic_block(a, v, 24, 48, 2)
    v = _basic_block(a, v, 48, 48, 1)
    v = _basic_block(a, v, 48, 48, 1)
    v = a.gap(v)
    a.fc(v, 48, NUM_CLASSES)
    return a


def mobilenet_tiny() -> Arch:
    a = Arch("mobilenet_tiny")
    v = a.conv(0, 3, 16, 3, 1, act="relu6")
    v = _inverted_residual(a, v, 16, 16, 1, 2)
    v = _inverted_residual(a, v, 16, 24, 2, 2)
    v = _inverted_residual(a, v, 24, 24, 1, 2)
    v = a.gap(v)
    a.fc(v, 24, NUM_CLASSES)
    return a


def mnasnet_tiny() -> Arch:
    a = Arch("mnasnet_tiny")
    v = a.conv(0, 3, 16, 3, 1, act="relu")
    # mnasnet mixes dw blocks with plain relu + a 5x5-ish stage (3x3 here)
    v = _inverted_residual(a, v, 16, 16, 1, 2, act="relu")
    v = _inverted_residual(a, v, 16, 32, 2, 3, act="relu")
    v = _inverted_residual(a, v, 32, 32, 1, 3, act="relu")
    v = a.gap(v)
    a.fc(v, 32, NUM_CLASSES)
    return a


def regnet_tiny() -> Arch:
    a = Arch("regnet_tiny")
    v = a.conv(0, 3, 8, 3, 1)
    v = _basic_block(a, v, 8, 16, 1)
    v = _basic_block(a, v, 16, 24, 2)
    v = _basic_block(a, v, 24, 32, 2)
    v = a.gap(v)
    a.fc(v, 32, NUM_CLASSES)
    return a


def regnet_wide() -> Arch:
    a = Arch("regnet_wide")
    v = a.conv(0, 3, 16, 3, 1)
    v = _basic_block(a, v, 16, 24, 1)
    v = _basic_block(a, v, 24, 40, 2)
    v = _basic_block(a, v, 40, 56, 2)
    v = _basic_block(a, v, 56, 56, 1)
    v = a.gap(v)
    a.fc(v, 56, NUM_CLASSES)
    return a


ZOO = {
    "convnet_tiny": convnet_tiny,
    "resnet_tiny": resnet_tiny,
    "resnet_wide": resnet_wide,
    "mobilenet_tiny": mobilenet_tiny,
    "mnasnet_tiny": mnasnet_tiny,
    "regnet_tiny": regnet_tiny,
    "regnet_wide": regnet_wide,
}


def get_arch(name: str) -> Arch:
    return ZOO[name]()


def init_params(arch: Arch, seed: int = 0):
    """He-init FP params as a list of jnp arrays in param_specs order."""
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in arch.param_specs():
        key, sub = jax.random.split(key)
        if name.startswith("w:"):
            fan_in = math.prod(shape[:-1]) if len(shape) > 2 else shape[0]
            std = math.sqrt(2.0 / max(fan_in, 1))
            out.append(jax.random.normal(sub, shape, jnp.float32) * std)
        else:
            out.append(jnp.zeros(shape, jnp.float32))
    return out
