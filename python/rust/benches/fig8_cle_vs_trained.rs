fn main() {}
