fn main() {}
