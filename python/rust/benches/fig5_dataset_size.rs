fn main() {}
