fn main() {}
