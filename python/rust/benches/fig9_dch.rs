fn main() {}
