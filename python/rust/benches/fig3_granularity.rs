fn main() {}
