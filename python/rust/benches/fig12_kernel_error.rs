fn main() {}
