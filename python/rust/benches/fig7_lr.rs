fn main() {}
