fn main() {}
