fn main() {}
