fn main() {}
