"""L2 FP model: shapes, training signal, calibration stats."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import archs, model


def _data(seed=0, batch=archs.BATCH):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random((batch, archs.INPUT_HW, archs.INPUT_HW,
                                archs.INPUT_CH), dtype=np.float32))
    y = jnp.asarray(rng.integers(0, archs.NUM_CLASSES, (batch,)).astype(np.float32))
    return x, y


@pytest.mark.parametrize("name", list(archs.ZOO))
def test_forward_shapes(name):
    a = archs.get_arch(name)
    p = archs.init_params(a)
    x, _ = _data()
    logits, feat, _ = model.forward(a, p, x)
    assert logits.shape == (archs.BATCH, archs.NUM_CLASSES)
    assert feat.shape[0] == archs.BATCH
    assert feat.shape[-1] == a.feat_channels()


@pytest.mark.parametrize("name", list(archs.ZOO))
def test_param_specs_match_init(name):
    a = archs.get_arch(name)
    p = archs.init_params(a)
    specs = a.param_specs()
    assert len(p) == len(specs)
    for (n, s), t in zip(specs, p):
        assert tuple(t.shape) == s, n


def test_fp_train_step_reduces_loss():
    a = archs.get_arch("convnet_tiny")
    p = archs.init_params(a)
    step = jax.jit(model.make_fp_train(a))
    n = len(p)
    m = [jnp.zeros_like(t) for t in p]
    v = [jnp.zeros_like(t) for t in p]
    x, y = _data()
    lr = jnp.array([3e-3], jnp.float32)
    losses = []
    for i in range(30):
        t = jnp.array([i + 1.0], jnp.float32)
        out = step(*p, *m, *v, t, lr, x, y)
        p, m, v = list(out[:n]), list(out[n:2 * n]), list(out[2 * n:3 * n])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] * 0.5, losses


def test_fp_stats_shapes_and_positivity():
    a = archs.get_arch("resnet_tiny")
    p = archs.init_params(a)
    x, _ = _data()
    stats = model.make_fp_stats(a)(*p, x)
    ch = a.value_channels()
    qv = a.quantized_values()
    assert len(stats) == len(qv)
    for vid, s in zip(qv, stats):
        assert s.shape == (ch[vid],)
        assert bool(jnp.all(s >= 0))


def test_fp_stats_input_stat_is_image_max():
    a = archs.get_arch("convnet_tiny")
    p = archs.init_params(a)
    x, _ = _data()
    stats = model.make_fp_stats(a)(*p, x)
    want = jnp.max(jnp.abs(x), axis=(0, 1, 2))
    np.testing.assert_allclose(np.asarray(stats[0]), np.asarray(want), rtol=1e-6)


def test_relu6_present_in_mobilenet():
    a = archs.get_arch("mobilenet_tiny")
    assert any(o.act == "relu6" for o in a.conv_ops())
    assert any(o.groups > 1 for o in a.conv_ops())  # depthwise


def test_residual_archs_have_adds():
    for name in ("resnet_tiny", "resnet_wide", "mobilenet_tiny",
                 "mnasnet_tiny", "regnet_tiny", "regnet_wide"):
        a = archs.get_arch(name)
        assert any(o.op == "add" for o in a.ops), name


def test_adam_update_moves_toward_gradient():
    p = [jnp.ones((4,), jnp.float32)]
    g = [jnp.ones((4,), jnp.float32)]
    m = [jnp.zeros((4,), jnp.float32)]
    v = [jnp.zeros((4,), jnp.float32)]
    new_p, _, _ = model.adam_update(p, g, m, v, jnp.float32(1.0), jnp.float32(0.1))
    assert bool(jnp.all(new_p[0] < p[0]))
