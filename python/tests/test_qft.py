"""QFT twin-graph: offline-subgraph relations, gradient flow, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import archs, model, qft


def _data(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.random((archs.BATCH, archs.INPUT_HW, archs.INPUT_HW,
                                   archs.INPUT_CH), dtype=np.float32))


def _init_trainables(a, mode, params, sv=0.02, f=0.03):
    pm = {n: v for (n, _), v in zip(a.param_specs(), params)}
    tr = []
    for n, s in a.trainable_specs(mode):
        kind = n.split(":")[0]
        if kind in ("w", "b"):
            tr.append(pm[n])
        elif kind == "sv":
            tr.append(jnp.full(s, sv, jnp.float32))
        elif kind == "swl":
            tr.append(jnp.ones(s, jnp.float32))
        else:  # f / swr
            tr.append(jnp.full(s, f, jnp.float32))
    return tr


# --------------------------------------------------- offline subgraph (Eq. 2)

def test_eq2_outer_product_decomposition():
    """Kernel grid is outer(1/S_a_prev, S_a*F): Eq. 2 exactly."""
    a = archs.get_arch("convnet_tiny")
    o = a.conv_ops()[1]
    tm = {
        f"sv:{o.inp}": jnp.asarray(np.linspace(0.01, 0.05, o.cin), jnp.float32),
        f"sv:{o.out}": jnp.asarray(np.linspace(0.02, 0.08, o.cout), jnp.float32),
        f"f:{o.name}": jnp.asarray([0.4], jnp.float32),
    }
    s_l, s_r = qft.kernel_scale_lw(tm, o, o.inp)
    su = np.asarray(tm[f"sv:{o.inp}"]) + qft.EPS
    sv = np.asarray(tm[f"sv:{o.out}"]) + qft.EPS
    np.testing.assert_allclose(np.asarray(s_l), 1.0 / su, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s_r), sv * (0.4 + qft.EPS), rtol=1e-6)
    # full grid = outer product
    grid = np.asarray(s_l)[:, None] * np.asarray(s_r)[None, :]
    assert grid.shape == (o.cin, o.cout)


def test_eq2_inversion_roundtrip():
    """Eqs. 3-4: dch co-vectors determine S_a and F; re-applying Eq. 2
    recovers the same kernel grid (the two parameterizations are equivalent)."""
    rng = np.random.default_rng(0)
    cin, cout = 8, 16
    s_wl = rng.uniform(0.5, 2.0, cin).astype(np.float32)
    s_wr = rng.uniform(0.01, 0.1, cout).astype(np.float32)
    s_wl_next = rng.uniform(0.5, 2.0, cout).astype(np.float32)
    # Eq. 3: S_a^{l-1} = 1/S_wL ; S_a^l = 1/S_wL^{l+1}
    s_a_prev = 1.0 / s_wl
    s_a = 1.0 / s_wl_next
    # Eq. 4: F = S_wR / S_a
    f = s_wr / s_a
    # Eq. 2 forward again:
    s_l2 = 1.0 / s_a_prev
    s_r2 = s_a * f
    np.testing.assert_allclose(s_l2, s_wl, rtol=1e-6)
    np.testing.assert_allclose(s_r2, s_wr, rtol=1e-6)


def test_depthwise_single_covector():
    a = archs.get_arch("mobilenet_tiny")
    dw = next(o for o in a.conv_ops() if o.groups > 1)
    names = [n for n, _ in a.trainable_specs("dch")]
    assert f"swr:{dw.name}" in names
    assert f"swl:{dw.name}" not in names


def test_fanout_shares_activation_scale():
    """Residual blocks: both consumers of a value derive S_wL from the same
    sv — the fan-out constraint of App. D is structural in our IR."""
    a = archs.get_arch("resnet_tiny")
    consumers: dict[int, int] = {}
    for o in a.conv_ops():
        consumers[o.inp] = consumers.get(o.inp, 0) + 1
    assert max(consumers.values()) >= 2  # some value feeds >= 2 convs
    # trainables contain exactly one sv per quantized value
    sv_names = [n for n, _ in a.trainable_specs("lw") if n.startswith("sv:")]
    assert len(sv_names) == len(set(sv_names)) == len(a.quantized_values())


# --------------------------------------------------------- student behaviour

@pytest.mark.parametrize("mode", ["lw", "dch"])
@pytest.mark.parametrize("name", ["convnet_tiny", "resnet_tiny", "mobilenet_tiny"])
def test_student_shapes(name, mode):
    a = archs.get_arch(name)
    p = archs.init_params(a)
    tr = _init_trainables(a, mode, p)
    logits, feat = qft.student_forward(a, mode, tr, _data())
    assert logits.shape == (archs.BATCH, archs.NUM_CLASSES)
    assert feat.shape[-1] == a.feat_channels()
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_student_dch_approaches_teacher_with_fine_grid():
    """With a very fine weight grid the dch student ~= FP teacher."""
    a = archs.get_arch("convnet_tiny")
    p = archs.init_params(a)
    x = _data()
    t_logits, t_feat, _ = model.forward(a, p, x)
    tr = _init_trainables(a, "dch", p, f=1e-5)  # fine 4b grid, tiny range...
    # ... a 1e-5 step clips heavily; instead use per-layer max/7 for no clip
    tr = []
    pm = {n: v for (n, _), v in zip(a.param_specs(), p)}
    for n, s in a.trainable_specs("dch"):
        kind = n.split(":")[0]
        if kind in ("w", "b"):
            tr.append(pm[n])
        elif kind == "swl":
            tr.append(jnp.ones(s, jnp.float32))
        else:
            w = pm[f"w:{n.split(':')[1]}"]
            tr.append(jnp.full(s, float(jnp.max(jnp.abs(w))) / 7.0, jnp.float32))
    s_logits, s_feat = qft.student_forward(a, "dch", tr, x)
    rel = float(jnp.linalg.norm(s_feat - t_feat) / jnp.linalg.norm(t_feat))
    assert rel < 0.35, rel  # 4b max-scaled: coarse but correlated


def test_kd_loss_zero_for_identical_feats():
    a = archs.get_arch("convnet_tiny")
    p = archs.init_params(a)
    x = _data()
    # dch student with *32b-like* grid: qmax huge via tiny scale? Instead,
    # check the loss formula directly.
    t_logits, t_feat, _ = model.forward(a, p, x)
    diff = jnp.zeros_like(t_feat)
    tf = t_feat.reshape(t_feat.shape[0], -1)
    l2 = jnp.mean(jnp.sum(diff.reshape(diff.shape[0], -1) ** 2, -1) /
                  (jnp.sum(tf * tf, -1) + 1e-6))
    assert float(l2) == 0.0


@pytest.mark.parametrize("mode", ["lw", "dch"])
def test_all_dof_receive_gradients(mode):
    """The paper's headline mechanism: every DoF class gets nonzero grads."""
    a = archs.get_arch("resnet_tiny")
    p = archs.init_params(a, seed=3)
    tr = _init_trainables(a, mode, p)
    x = _data(1)
    g = jax.grad(lambda t: qft.kd_loss(a, mode, t, p, x, 0.0))(tr)
    by_kind: dict[str, float] = {}
    for (n, _), gi in zip(a.trainable_specs(mode), g):
        kind = n.split(":")[0]
        by_kind[kind] = max(by_kind.get(kind, 0.0), float(jnp.abs(gi).max()))
    for kind in ("w", "b"):
        assert by_kind[kind] > 0, by_kind
    scale_kinds = ("sv", "f") if mode == "lw" else ("swl", "swr")
    for kind in scale_kinds:
        assert by_kind[kind] > 0, by_kind


def test_train_scales_gate_blocks_scale_updates():
    a = archs.get_arch("convnet_tiny")
    p = archs.init_params(a, seed=2)
    tr = _init_trainables(a, "lw", p)
    n = len(tr)
    m = [jnp.zeros_like(t) for t in tr]
    v = [jnp.zeros_like(t) for t in tr]
    step = jax.jit(qft.make_qft_train(a, "lw"))
    one = jnp.array([1.0], jnp.float32)
    zero = jnp.array([0.0], jnp.float32)
    lr = jnp.array([1e-3], jnp.float32)
    out = step(*tr, *m, *v, one, lr, zero, zero, *p, _data())
    new_tr = out[:n]
    for (name, _), before, after in zip(a.trainable_specs("lw"), tr, new_tr):
        kind = name.split(":")[0]
        if kind in ("sv", "f"):
            np.testing.assert_array_equal(np.asarray(before), np.asarray(after))
    # weights did move
    moved = any(
        not np.array_equal(np.asarray(b), np.asarray(af))
        for (nm, _), b, af in zip(a.trainable_specs("lw"), tr, new_tr)
        if nm.startswith("w:"))
    assert moved


def test_qft_training_reduces_kd_loss():
    """A few QFT steps must reduce the distillation loss (both modes)."""
    a = archs.get_arch("convnet_tiny")
    p = archs.init_params(a, seed=4)
    x = _data(5)
    for mode in ("lw", "dch"):
        tr = _init_trainables(a, mode, p)
        n = len(tr)
        m = [jnp.zeros_like(t) for t in tr]
        v = [jnp.zeros_like(t) for t in tr]
        step = jax.jit(qft.make_qft_train(a, mode))
        lr = jnp.array([1e-3], jnp.float32)
        one = jnp.array([1.0], jnp.float32)
        zero = jnp.array([0.0], jnp.float32)
        losses = []
        for i in range(25):
            t = jnp.array([i + 1.0], jnp.float32)
            out = step(*tr, *m, *v, t, lr, zero, one, *p, x)
            tr = list(out[:n])
            m, v = list(out[n:2 * n]), list(out[2 * n:3 * n])
            losses.append(float(out[-1]))
        assert losses[-1] < losses[0] * 0.9, (mode, losses[:3], losses[-3:])


def test_ce_mix_changes_loss():
    a = archs.get_arch("convnet_tiny")
    p = archs.init_params(a, seed=6)
    tr = _init_trainables(a, "lw", p)
    x = _data(7)
    l0 = float(qft.kd_loss(a, "lw", tr, p, x, 0.0))
    l1 = float(qft.kd_loss(a, "lw", tr, p, x, 1.0))
    assert l0 != l1


def test_scale_mask_identifies_scale_dof():
    a = archs.get_arch("resnet_tiny")
    for mode in ("lw", "dch"):
        mask = qft._scale_mask(a, mode)
        names = [n for n, _ in a.trainable_specs(mode)]
        for mk, n in zip(mask, names):
            expect = 1.0 if n.split(":")[0] in ("sv", "f", "swl", "swr") else 0.0
            assert mk == expect, n
