"""AOT manifest/artifact consistency (runs against a built artifacts/ dir)."""

import json
import os

import pytest

from compile import archs

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built (run make artifacts)")


def _manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_covers_zoo():
    m = _manifest()
    for name in archs.ZOO:
        assert name in m["archs"], name


def test_artifact_files_exist():
    m = _manifest()
    for arch in m["archs"].values():
        for art in arch["artifacts"].values():
            path = os.path.join(ART, art["file"])
            assert os.path.exists(path), path
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head, path


def test_manifest_shapes_match_arch_specs():
    m = _manifest()
    for name in archs.ZOO:
        a = archs.get_arch(name)
        spec = m["archs"][name]
        assert [tuple(p["shape"]) for p in spec["params"]] == \
               [s for _, s in a.param_specs()]
        for mode in ("lw", "dch"):
            assert [tuple(p["shape"]) for p in spec["trainables"][mode]] == \
                   [s for _, s in a.trainable_specs(mode)]


def test_qft_train_io_arity():
    """inputs = 3*T + 4 scalars + P teacher + images; outputs = 3*T + loss."""
    m = _manifest()
    for name in archs.ZOO:
        a = archs.get_arch(name)
        np_ = len(a.param_specs())
        for mode in ("lw", "dch"):
            nt = len(a.trainable_specs(mode))
            art = m["archs"][name]["artifacts"][f"qft_train_{mode}"]
            assert len(art["inputs"]) == 3 * nt + 4 + np_ + 1
            assert len(art["outputs"]) == 3 * nt + 1


def test_kernel_artifacts_present():
    m = _manifest()
    assert "qmatmul" in m["kernels"] and "fakequant" in m["kernels"]
    for k in m["kernels"].values():
        assert os.path.exists(os.path.join(ART, k["file"]))
