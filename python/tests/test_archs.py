"""Architecture-IR invariants: the manifest contract both layers rely on."""

import pytest

from compile import archs


@pytest.mark.parametrize("name", list(archs.ZOO))
def test_conv_channel_chains_are_consistent(name):
    a = archs.get_arch(name)
    ch = a.value_channels()
    for o in a.ops:
        if o.op == "conv":
            assert ch[o.inp] == o.cin, o.name
            assert ch[o.out] == o.cout, o.name
            if o.groups > 1:
                assert o.groups == o.cin == o.cout, "only depthwise supported"
        elif o.op == "add":
            assert ch[o.a] == ch[o.b] == ch[o.out]


@pytest.mark.parametrize("name", list(archs.ZOO))
def test_values_produced_before_use(name):
    a = archs.get_arch(name)
    seen = {0}
    for o in a.ops:
        uses = {"conv": [o.inp], "add": [o.a, o.b], "gap": [o.inp], "fc": [o.inp]}[o.op]
        for u in uses:
            assert u in seen, f"{o.name} uses value {u} before production"
        seen.add(o.out)


@pytest.mark.parametrize("name", list(archs.ZOO))
def test_value_ids_unique_and_dense(name):
    a = archs.get_arch(name)
    outs = [o.out for o in a.ops]
    assert len(outs) == len(set(outs))
    assert sorted([0] + outs) == list(range(a.nvals))


@pytest.mark.parametrize("name", list(archs.ZOO))
def test_signedness_rules(name):
    a = archs.get_arch(name)
    signed = a.value_signed()
    assert signed[0] is False  # images in [0,1]
    for o in a.ops:
        if o.op in ("conv", "add"):
            assert signed[o.out] == (o.act == "none"), o.name


@pytest.mark.parametrize("name", list(archs.ZOO))
def test_quantized_values_cover_all_conv_inputs(name):
    """Every conv input must carry an encoding (the S_wL = 1/S_a link)."""
    a = archs.get_arch(name)
    qv = set(a.quantized_values())
    for o in a.conv_ops():
        assert o.inp in qv, f"{o.name} input value {o.inp} not quantized"


@pytest.mark.parametrize("name", list(archs.ZOO))
def test_manifest_json_is_self_consistent(name):
    a = archs.get_arch(name)
    j = a.to_json()
    assert j["name"] == name
    assert len(j["ops"]) == len(a.ops)
    assert len(j["params"]) == len(a.param_specs())
    for mode in ("lw", "dch"):
        assert len(j["trainables"][mode]) == len(a.trainable_specs(mode))
    assert j["backbone_value"] == a.backbone_value()
    # every op's out is in value_channels
    for o in j["ops"]:
        assert str(o["out"]) in j["value_channels"]


@pytest.mark.parametrize("name", list(archs.ZOO))
def test_trainable_specs_lw_structure(name):
    a = archs.get_arch(name)
    ch = a.value_channels()
    specs = dict(a.trainable_specs("lw"))
    # one sv per quantized value with the right channel count
    for v in a.quantized_values():
        assert specs[f"sv:{v}"] == (ch[v],)
    # one scalar F per conv
    for o in a.conv_ops():
        assert specs[f"f:{o.name}"] == (1,)


@pytest.mark.parametrize("name", list(archs.ZOO))
def test_trainable_specs_dch_structure(name):
    a = archs.get_arch(name)
    specs = dict(a.trainable_specs("dch"))
    for o in a.conv_ops():
        assert specs[f"swr:{o.name}"] == (o.cout,)
        if o.groups == 1:
            assert specs[f"swl:{o.name}"] == (o.cin,)
        else:
            assert f"swl:{o.name}" not in specs


def test_zoo_has_six_table1_analogues_plus_quickstart():
    assert len(archs.ZOO) == 7
    assert "convnet_tiny" in archs.ZOO  # quickstart net


def test_backbone_is_pre_gap_feature_map():
    for name in archs.ZOO:
        a = archs.get_arch(name)
        gap = next(o for o in a.ops if o.op == "gap")
        assert a.backbone_value() == gap.inp
