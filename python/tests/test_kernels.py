"""L1 kernel correctness: Pallas vs pure-jnp oracle (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.fakequant import fakequant
from compile.kernels.qmatmul import qmatmul

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


# ---------------------------------------------------------------- fakequant

@settings(**SETTINGS)
@given(
    rows=st.integers(1, 48), cols=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
    qmax=st.sampled_from([1.0, 7.0, 127.0, 255.0]),
    signed=st.booleans(),
)
def test_fakequant_matches_ref_2d(rows, cols, seed, qmax, signed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (rows, cols))
    s = jnp.asarray(rng.uniform(0.01, 0.5, (cols,)).astype(np.float32))
    qmin = -qmax if signed else 0.0
    got = fakequant(x, s[None, :], qmin, qmax)
    want = ref.fakequant_ref(x, s[None, :], qmin, qmax)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@settings(**SETTINGS)
@given(
    shape=st.sampled_from([(3,), (4, 5), (2, 3, 4), (2, 3, 4, 5)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fakequant_nd_scalar_scale(shape, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, shape)
    s = jnp.asarray(np.float32(0.07))
    got = fakequant(x, s, -7.0, 7.0)
    want = ref.fakequant_ref(x, s, -7.0, 7.0)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_fakequant_outer_product_scale():
    """Doubly-channelwise grid: s = s_l ⊗ s_r broadcast over a 4-D kernel."""
    rng = np.random.default_rng(0)
    w = _rand(rng, (3, 3, 8, 16), 0.2)
    s_l = jnp.asarray(rng.uniform(0.5, 2.0, (8,)).astype(np.float32))
    s_r = jnp.asarray(rng.uniform(0.01, 0.1, (16,)).astype(np.float32))
    s = s_l[None, None, :, None] * s_r[None, None, None, :]
    got = fakequant(w, s, -7.0, 7.0)
    want = ref.fakequant_ref(w, s, -7.0, 7.0)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_fakequant_large_blocked_path():
    """Exercise the tiled (grid > 1) Pallas dispatch."""
    rng = np.random.default_rng(1)
    x = _rand(rng, (512, 256))
    s = jnp.full((512, 256), 0.05, jnp.float32)
    got = fakequant(x, s, -127.0, 127.0)
    want = ref.fakequant_ref(x, s, -127.0, 127.0)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_fakequant_idempotent():
    """fq(fq(x)) == fq(x): quantized points are fixed points of the grid."""
    rng = np.random.default_rng(2)
    x = _rand(rng, (32, 32))
    s = jnp.asarray(np.float32(0.1))
    once = fakequant(x, s, -7.0, 7.0)
    twice = fakequant(once, s, -7.0, 7.0)
    assert_allclose(np.asarray(once), np.asarray(twice), rtol=0, atol=1e-7)


def test_fakequant_values_on_grid():
    rng = np.random.default_rng(3)
    x = _rand(rng, (64,))
    s = 0.13
    y = np.asarray(fakequant(x, jnp.float32(s), -7.0, 7.0))
    q = y / s
    assert np.all(np.abs(q - np.round(q)) < 1e-4)
    assert np.all(np.round(q) >= -7) and np.all(np.round(q) <= 7)


# ----------------------------------------------------------- STE gradients

def test_fakequant_grad_x_is_clip_mask():
    rng = np.random.default_rng(4)
    x = _rand(rng, (128,), 2.0)
    s = jnp.float32(0.2)  # range ±1.4, plenty of clipping on N(0,4)
    g = jax.grad(lambda x_: jnp.sum(fakequant(x_, s, -7.0, 7.0)))(x)
    q = np.asarray(x) / 0.2
    inside = (q >= -7) & (q <= 7)
    assert_allclose(np.asarray(g), inside.astype(np.float32), atol=1e-6)


def test_fakequant_grad_s_matches_lsq_formula():
    rng = np.random.default_rng(5)
    x = _rand(rng, (64, 8))
    s = jnp.asarray(rng.uniform(0.05, 0.3, (8,)).astype(np.float32))
    g = jax.grad(lambda s_: jnp.sum(fakequant(x, s_[None, :], -7.0, 7.0)))(s)
    ones = jnp.ones_like(x)
    _, want = ref.fakequant_grads_ref(ones, x, s[None, :], -7.0, 7.0)
    assert_allclose(np.asarray(g), np.asarray(want).reshape(-1), rtol=1e-5,
                    atol=1e-6)


def test_fakequant_grad_s_sign():
    """Scale gradient must push s up when everything clips (reduce clipping)."""
    x = jnp.full((32,), 10.0, jnp.float32)
    s = jnp.float32(0.1)  # max representable 0.7 << 10 -> heavy clipping
    # d/ds of sum(fq) = sum(r) = 32*7 > 0: growing s grows the output toward x
    g = jax.grad(lambda s_: jnp.sum(fakequant(x, s_, -7.0, 7.0)))(s)
    assert float(g) > 0


# ------------------------------------------------------------------ qmatmul

@settings(**SETTINGS)
@given(
    m=st.integers(1, 32), k=st.integers(1, 32), n=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k))
    w = _rand(rng, (k, n), 0.3)
    s_l = jnp.asarray(rng.uniform(0.5, 2.0, (k,)).astype(np.float32))
    s_r = jnp.asarray(rng.uniform(0.01, 0.1, (n,)).astype(np.float32))
    got = qmatmul(x, w, s_l, s_r, -7.0, 7.0)
    s = s_l[:, None] * s_r[None, :]
    want = ref.qmatmul_ref(x, w, s, -7.0, 7.0)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_qmatmul_blocked_path():
    rng = np.random.default_rng(7)
    x = _rand(rng, (256, 64))
    w = _rand(rng, (64, 256), 0.3)
    s_l = jnp.ones((64,), jnp.float32)
    s_r = jnp.full((256,), 0.05, jnp.float32)
    got = qmatmul(x, w, s_l, s_r, -7.0, 7.0)
    want = ref.qmatmul_ref(x, w, s_l[:, None] * s_r[None, :], -7.0, 7.0)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_qmatmul_grads_match_composed():
    """qmatmul's custom backward == autodiff of x @ fakequant(w, s_l⊗s_r)."""
    rng = np.random.default_rng(8)
    x = _rand(rng, (16, 8))
    w = _rand(rng, (8, 12), 0.3)
    s_l = jnp.asarray(rng.uniform(0.5, 2.0, (8,)).astype(np.float32))
    s_r = jnp.asarray(rng.uniform(0.02, 0.1, (12,)).astype(np.float32))

    def fused(x, w, s_l, s_r):
        return jnp.sum(qmatmul(x, w, s_l, s_r, -7.0, 7.0) ** 2)

    def composed(x, w, s_l, s_r):
        s = s_l[None, :, None] * s_r[None, None, :]
        wq = fakequant(w[None], s, -7.0, 7.0)[0]
        return jnp.sum((x @ wq) ** 2)

    g1 = jax.grad(fused, argnums=(0, 1, 2, 3))(x, w, s_l, s_r)
    g2 = jax.grad(composed, argnums=(0, 1, 2, 3))(x, w, s_l, s_r)
    for a, b in zip(g1, g2):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_qmatmul_grad_nonzero_all_inputs():
    rng = np.random.default_rng(9)
    x = _rand(rng, (8, 8))
    w = _rand(rng, (8, 8), 0.3)
    s_l = jnp.ones((8,), jnp.float32)
    s_r = jnp.full((8,), 0.05, jnp.float32)
    g = jax.grad(lambda *a: jnp.sum(qmatmul(*a, -7.0, 7.0) ** 2),
                 argnums=(0, 1, 2, 3))(x, w, s_l, s_r)
    for gi in g:
        assert float(jnp.abs(gi).max()) > 0
