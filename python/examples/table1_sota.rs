fn main() {}
