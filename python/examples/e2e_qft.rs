fn main() {}
