fn main() {}
