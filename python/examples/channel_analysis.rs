fn main() {}
