//! Quickstart: quantize one tiny net with QFT and report the degradation.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Pipeline (all through AOT HLO executables — python never runs here):
//!   1. load the PJRT runtime + manifest
//!   2. pretrain (or load the cached) FP teacher
//!   3. the sole pre-QFT step: naive-max activation calibration, PPQ-MMSE
//!      weight ranges, rescale factors via inversion of Eq. 2
//!   4. QFT: joint KD finetune of ALL DoF (weights, biases, activation
//!      vector scales == the CLE DoF, rescale factors)
//!   5. evaluate the 4b-weight deployment vs the FP baseline

use anyhow::Result;
use qft::coordinator::{eval, experiments, metrics, qft as qft_stage};
use qft::quant::deploy::Mode;
use qft::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::load("artifacts")?;
    println!("platform: {}", rt.platform());

    let arch = "convnet_tiny";
    let t = experiments::teacher_ctx(&rt, arch)?;
    println!("teacher fp top-1: {:.1}%", t.fp_acc * 100.0);

    let cfg = qft_stage::QftConfig::fast(Mode::Lw);
    let span = metrics::Span::start(&rt, "qft");
    let r = qft_stage::run_qft(&rt, arch, &t.params, &cfg)?;
    println!("{}", span.finish());

    let acc_init = eval::eval_q(&rt, arch, &r.init, Mode::Lw, 512, 0)?;
    let acc_qft = eval::eval_q(&rt, arch, &r.trainables, Mode::Lw, 512, 0)?;
    println!(
        "W4A8 layerwise | mmse init: {:.1}% (degr {:+.2}) | after QFT: {:.1}% (degr {:+.2})",
        acc_init * 100.0,
        (acc_init - t.fp_acc) * 100.0,
        acc_qft * 100.0,
        (acc_qft - t.fp_acc) * 100.0,
    );
    println!(
        "kd-loss {:.4} -> {:.4} over {} steps",
        r.losses.first().unwrap(),
        r.losses.last().unwrap(),
        r.losses.len()
    );
    Ok(())
}
