//! Figures 13–17: per-channel optimal-range and error scatter analysis.
//!
//! Prints one row per (layer, output-channel) of regnet_tiny:
//!   * mmse-optimal slice range normalized by whole-kernel naive max — the
//!     Fig. 13 "very few slices call for unclipped representation" picture
//!   * per-slice 4b error under layerwise / channelwise / CLE grids
//!     (Figs. 14, 15, 16)
//!
//! ```text
//! cargo run --release --example channel_analysis [arch]
//! ```

use anyhow::Result;
use qft::coordinator::experiments;
use qft::runtime::Runtime;

fn main() -> Result<()> {
    let arch = std::env::args().nth(1).unwrap_or_else(|| "regnet_tiny".into());
    let rt = Runtime::load("artifacts")?;
    let pts = experiments::channel_analysis(&rt, &arch)?;

    println!("# Figs. 13-16 scatter data for {arch}");
    println!(
        "{:<10} {:>4} {:>14} {:>10} {:>10} {:>10}",
        "layer", "ch", "opt_range/naive", "err_lw", "err_chw", "err_cle"
    );
    for p in &pts {
        println!(
            "{:<10} {:>4} {:>14.3} {:>10.4} {:>10.4} {:>10.4}",
            p.layer, p.channel, p.norm_opt_range, p.err_layerwise, p.err_channelwise, p.err_cle
        );
    }

    // Fig. 13 headline: how many slices want unclipped (>= naive) range?
    let unclipped = pts.iter().filter(|p| p.norm_opt_range >= 0.99).count();
    println!(
        "\n[fig13] {}/{} slices mmse-optimal at unclipped range; median ratio {:.2}",
        unclipped,
        pts.len(),
        median(pts.iter().map(|p| p.norm_opt_range))
    );
    // Figs. 14-16 headline: total error by scheme
    let tot = |f: &dyn Fn(&experiments::ChannelPoint) -> f32| -> f32 {
        pts.iter().map(|p| f(p) * f(p)).sum::<f32>().sqrt()
    };
    println!(
        "[fig14-16] total slice error: layerwise {:.4} | CLE {:.4} | channelwise {:.4}",
        tot(&|p| p.err_layerwise),
        tot(&|p| p.err_cle),
        tot(&|p| p.err_channelwise)
    );
    Ok(())
}

fn median(vals: impl Iterator<Item = f32>) -> f32 {
    let mut v: Vec<f32> = vals.collect();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}
