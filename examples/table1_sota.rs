//! Table 1 driver: QFT vs heuristic PTQ baselines across the zoo (the
//! fast profile; use `repro table1` without `--fast` for the full schedule).
//!
//! ```text
//! cargo run --release --example table1_sota [arch1,arch2,...]
//! ```

use anyhow::Result;
use qft::coordinator::experiments;
use qft::runtime::Runtime;

fn main() -> Result<()> {
    let archs = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "resnet_tiny,mobilenet_tiny,regnet_tiny".into());
    let names: Vec<&str> = archs.split(',').collect();
    let rt = Runtime::load("artifacts")?;
    let rows = experiments::table1(&rt, &names, true)?;
    experiments::print_rows("Table 1 (fast profile): QFT vs PTQ baselines", &rows);

    // the paper's claim structure: QFT <= 1% degradation for most nets,
    // CLE+QFT at least as good as QFT on the nets where CLE helps
    let qft_rows: Vec<_> = rows.iter().filter(|r| r.config.starts_with("QFT 4/8")).collect();
    let sub1 = qft_rows.iter().filter(|r| r.degradation() < 0.015).count();
    println!("\nQFT 4/8 lw sub-1.5%-degradation: {}/{}", sub1, qft_rows.len());
    Ok(())
}
