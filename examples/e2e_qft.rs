//! End-to-end validation driver (EXPERIMENTS.md §E2E): the full system on a
//! real small workload, proving all three layers compose.
//!
//! * L1 Pallas fake-quant/qmatmul kernels — inside every student step
//! * L2 AOT JAX graphs — fp_train / fp_stats / qft_train / q_eval
//! * L3 rust coordinator — data, calibration, heuristics, the QFT loop,
//!   integer-deployment cross-check
//!
//! Workload: mobilenet_tiny (depthwise + relu6 — the paper's hard case) on
//! the synthetic 10-class task.  Stages: pretrain teacher → calibrate →
//! MMSE init → QFT (paper schedule: 12 epochs, cosine + /2 reloads) →
//! evaluate + integer-simulation parity check.  Loss curve and timing are
//! printed for the experiment log.

use anyhow::Result;
use qft::coordinator::{eval, metrics, pretrain, qft as qft_stage};
use qft::quant::deploy::{self, Mode};
use qft::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::load("artifacts")?;
    println!("platform: {}", rt.platform());
    let arch_name = "mobilenet_tiny";
    let arch = rt.manifest.arch(arch_name)?.clone();

    // ---- stage 1: FP teacher -------------------------------------------
    let span = metrics::Span::start(&rt, "stage1-pretrain");
    let teacher = pretrain::teacher(&rt, arch_name, &pretrain::PretrainConfig::default())?;
    let fp_acc = eval::eval_fp(&rt, arch_name, &teacher, 512, 0)?;
    println!("{}", span.finish());
    println!("[stage1] teacher fp top-1 = {:.2}%", fp_acc * 100.0);

    // ---- stage 2+3: calibrate + init + QFT ------------------------------
    let cfg = qft_stage::QftConfig::standard(Mode::Lw);
    println!(
        "[stage2] QFT config: {} epochs x {} images, batch {}, base lr {:.0e}, label-free KD",
        cfg.epochs, cfg.images_per_epoch, arch.batch, cfg.base_lr
    );
    let span = metrics::Span::start(&rt, "stage3-qft");
    let r = qft_stage::run_qft(&rt, arch_name, &teacher, &cfg)?;
    let rep = span.finish();
    println!("{rep}");
    println!(
        "[stage3] steps/s = {:.1}, mean step = {:.2} ms",
        r.losses.len() as f64 / (rep.wall_ms / 1e3),
        rep.wall_ms / r.losses.len() as f64
    );
    // loss curve (decimated)
    print!("[stage3] kd-loss curve:");
    for (i, l) in r.losses.iter().enumerate() {
        if i % (r.losses.len() / 12).max(1) == 0 {
            print!(" {l:.4}");
        }
    }
    println!(" -> {:.4}", r.losses.last().unwrap());

    // ---- stage 4: evaluation -------------------------------------------
    let acc_init = eval::eval_q(&rt, arch_name, &r.init, Mode::Lw, 512, 0)?;
    let acc_qft = eval::eval_q(&rt, arch_name, &r.trainables, Mode::Lw, 512, 0)?;
    println!(
        "[stage4] W4A8-lw: init degr {:+.2}%, QFT degr {:+.2}% (fp {:.2}%)",
        (acc_init - fp_acc) * 100.0,
        (acc_qft - fp_acc) * 100.0,
        fp_acc * 100.0
    );

    // ---- stage 5: deployability cross-checks ----------------------------
    // (a) AOT q_eval vs pure-rust fake-quant simulator
    let acc_rust = eval::eval_q_rust(&arch, &r.trainables, Mode::Lw, 512, 0);
    // (b) fully-integer online pipeline (quantized bias, integer relu,
    //     multiplicative recode)
    let ds = qft::data::Dataset::new(0);
    let mut agree = 0usize;
    let mut total = 0usize;
    let mut scratch = deploy::DeployScratch::new();
    let model = deploy::DeployedModel::prepare(&arch, &r.trainables, Mode::Lw);
    for i in 0..16 {
        let (x, _, _) = ds.batch(qft::data::Split::Val, i * 8, 8);
        let (lf, _) = deploy::forward_fakequant(&arch, &r.trainables, Mode::Lw, &x);
        let (li, _) = model.forward_batch_feat(&x, &mut scratch);
        agree += lf
            .argmax_lastdim()
            .iter()
            .zip(&li.argmax_lastdim())
            .filter(|(a, b)| a == b)
            .count();
        total += 8;
    }
    println!(
        "[stage5] parity: q_eval(HLO) {:.2}% vs rust-sim {:.2}%; integer-pipeline argmax agreement {:.1}%",
        acc_qft * 100.0,
        acc_rust * 100.0,
        agree as f32 / total as f32 * 100.0
    );
    println!("e2e_qft: OK");
    Ok(())
}
