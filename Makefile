# QFT reproduction — build / verify entry points.

.PHONY: check build test fmt artifacts bench-serve par-bench

# Tier-1 verification: release build, full test suite, formatting.
check:
	cargo build --release
	cargo test -q
	cargo fmt --check

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt

# Export the AOT HLO artifacts + manifest (one-time; needs the image's
# JAX/XLA python environment).
artifacts:
	cd python/compile && python3 aot.py --out ../../artifacts

# Serving throughput bench (works with or without artifacts; emits
# BENCH_serve.json).
bench-serve:
	cargo bench --bench serve_throughput

# Parallel kernel engine bench: serial vs pooled single-request conv/GEMM
# at 1/2/4 threads (emits BENCH_par.json).
par-bench:
	cargo bench --bench par_kernels
