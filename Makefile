# QFT reproduction — build / verify entry points.

.PHONY: check build test fmt artifacts bench bench-serve par-bench bench-gemm bench-net \
        bench-smoke bench-gate bench-baseline obs-overhead bench-swap

# Tier-1 verification: release build, full test suite, formatting.
check:
	cargo build --release
	cargo test -q
	cargo fmt --check

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt

# Export the AOT HLO artifacts + manifest (one-time; needs the image's
# JAX/XLA python environment).
artifacts:
	cd python/compile && python3 aot.py --out ../../artifacts

# Aggregate perf trajectory: every perf bench, landing BENCH_gemm.json,
# BENCH_par.json, BENCH_serve.json, BENCH_swap.json and BENCH_net.json at
# the repo root.
bench: bench-gemm par-bench bench-serve bench-swap bench-net

# Serving throughput bench: lw / dch / lw-i8 backend sweep at 1/2/4 workers
# (works with or without artifacts; emits BENCH_serve.json).
bench-serve:
	cargo bench --bench serve_throughput

# Parallel kernel engine bench: serial vs pooled single-request conv/GEMM
# at 1/2/4 threads (emits BENCH_par.json).
par-bench:
	cargo bench --bench par_kernels

# GEMM micro-kernel bench: scalar reference vs panel-packed register-blocked
# f32 kernel vs the runtime-dispatched integer kernels (i8 byte panels and
# W4 nibble panels), GFLOP/s over ResNet- and edge-shaped GEMMs (emits
# BENCH_gemm.json, including the dispatched kernel path).  Prefix with
# QFT_KERNEL=scalar|avx2|vnni|neon to force a dispatch path.
bench-gemm:
	cargo bench --bench gemm_kernels

# Open-loop wire-latency bench: Poisson arrivals over real TCP against the
# qft::net front-end, backend x connections x offered-rate sweep at a fixed
# 2-worker engine; latency is measured from the *scheduled* send instant so
# queueing delay lands in the percentiles (no coordinated omission).  Emits
# BENCH_net.json with p50/p99/p99.9-under-load; the lw-i8 row at 4 conns /
# 200 rps feeds the perf gate.
bench-net:
	cargo bench --bench net_load

# Hot-swap stall bench: closed-loop latency with the fleet slot steady vs
# promoting between bit-identical versions every ~500us for the whole run
# (emits BENCH_swap.json with the swapping/steady p99 stall ratio).
bench-swap:
	cargo bench --bench swap_stall

# Observability overhead gate: lw-i8 closed loop with qft::obs on vs off
# (interleaved rounds); fails if the obs-on p50 regresses more than 3%
# (+25us slack; QFT_OBS_OVERHEAD_TOL override).  Emits BENCH_obs.json and
# a validated OBS_metrics.prom Prometheus exposition.
obs-overhead:
	cargo bench --bench obs_overhead

# CI harness smoke: every perf bench at a tiny iteration count, so the
# bench binaries cannot rot without breaking the build.
bench-smoke:
	QFT_BENCH_SMOKE=1 cargo bench --bench gemm_kernels
	QFT_BENCH_SMOKE=1 cargo bench --bench par_kernels
	QFT_BENCH_SMOKE=1 cargo bench --bench serve_throughput
	QFT_BENCH_SMOKE=1 cargo bench --bench swap_stall
	QFT_BENCH_SMOKE=1 cargo bench --bench obs_overhead
	QFT_BENCH_SMOKE=1 cargo bench --bench net_load

# Perf-regression gate: rerun the gemm + serve + net benches in their
# pinned configuration, then compare the gated metrics (kernel speedup
# geomeans, the i8/W4 ratio floors, lw-i8 serving p50s, the lw-i8 wire
# p99) against the committed BENCH_baseline.json.  Per-metric tolerance:
# QFT_BENCH_GATE_TOL override > the baseline entry's own `tol` (the ratio
# floors pin 0%) > the global `tolerance` (15%).  SIMD-only floors are
# skipped when the gemm bench reports scalar dispatch; the wire-latency
# metric is skipped (visibly, never faked) when BENCH_net.json is absent
# or smoke-tainted.  Emits a markdown delta table (and the CI job
# summary).
bench-gate: bench-gemm bench-serve bench-net
	cargo bench --bench bench_gate

# Re-baseline the perf gate from a fresh local run on THIS machine: reruns
# the pinned benches, rewrites BENCH_baseline.json (preserving the global
# tolerance, the comment, and any per-metric `tol` pins), and prints a
# delta table vs the previous baseline.  Review + commit the result; run
# on a SIMD-capable host or the integer-ratio floors will reflect scalar
# kernels.
bench-baseline: bench-gemm bench-serve bench-net
	QFT_BENCH_WRITE_BASELINE=1 cargo bench --bench bench_gate
